//! End-to-end loopback tests for the `cer-serve` network front end:
//! real TCP sockets against a real worker plane, proving the PR's
//! acceptance invariants:
//!
//! (a) socket replies are **bit-identical** to the in-process engine;
//! (b) a full admission queue answers `429 + Retry-After` without
//!     blocking the listener (health stays up);
//! (c) an already-expired deadline answers `504` without the request
//!     ever being admitted or reaching a worker;
//! (d) hot-reload under fire never serves a torn read — every reply is
//!     exactly the old weights' output or the new weights' output — and
//!     the displaced `Arc<PackMap>` is released once drained;
//! (e) drain/SIGTERM finishes in-flight work and exits cleanly
//!     (in-process via `ServeHandle::shutdown`, and for real via a
//!     `repro serve-net` subprocess killed with SIGTERM).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Weak};
use std::time::{Duration, Instant};

use cer::coordinator::batcher::BatcherConfig;
use cer::coordinator::engine::{Engine, PackOptions};
use cer::coordinator::server::ServerConfig;
use cer::formats::{Dense, FormatKind};
use cer::pack::map::PackMap;
use cer::serve::http::{json_f32_array, HttpClient, Request};
use cer::serve::{serve, HotRouter, ServeHandle, ServeOptions, ServeState};
use cer::util::json;
use cer::util::Rng;

const IN_DIM: usize = 6;
const OUT_DIM: usize = 4;

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("serve-net-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn write_pack(dir: &Path, file: &str, seed: u64) -> PathBuf {
    let path = dir.join(format!("{file}.cerpack"));
    let mut rng = Rng::new(seed);
    let d = Dense::from_vec(
        OUT_DIM,
        IN_DIM,
        (0..OUT_DIM * IN_DIM).map(|_| rng.f32() - 0.5).collect(),
    );
    let bias = (0..OUT_DIM).map(|_| rng.f32() - 0.5).collect();
    let e = Engine::native_fixed(vec![("fc".to_string(), d, bias)], FormatKind::Cser);
    e.save_pack(&path, file, "serve-net test").unwrap();
    path
}

fn server_cfg(max_batch: usize, max_delay_us: u64) -> ServerConfig {
    ServerConfig {
        batcher: BatcherConfig {
            max_batch,
            max_delay_us,
        },
        threads: Some(1),
        ..ServerConfig::default()
    }
}

fn spawn(pack: &Path, name: &str, workers: usize, opts: ServeOptions, cfg: ServerConfig) -> ServeHandle {
    let router = HotRouter::new(cfg, workers);
    router.add_pack(name, pack).unwrap();
    serve("127.0.0.1:0", ServeState::new(router, opts)).unwrap()
}

fn infer_req(input: &[f32]) -> Request {
    Request::new("POST", "/v1/infer").json(format!("{{\"input\":{}}}", json_f32_array(input)))
}

/// Parse a 200 reply's `output` array into f32 bit patterns.
fn output_bits(body: &str) -> Vec<u32> {
    let doc = json::parse(body).unwrap_or_else(|e| panic!("bad reply {body:?}: {e}"));
    doc.get("output")
        .unwrap_or_else(|| panic!("no output in {body:?}"))
        .items()
        .iter()
        .map(|v| (v.as_f64().unwrap() as f32).to_bits())
        .collect()
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

// ---------------------------------------------------------------- (a)

#[test]
fn socket_replies_are_bit_identical_to_in_process_engine() {
    let dir = scratch_dir("exact");
    let pack = write_pack(&dir, "exact", 42);
    let mut reference = PackOptions::new(&pack).open().unwrap();
    let handle = spawn(
        &pack,
        "exact",
        2,
        ServeOptions::default(),
        server_cfg(8, 100),
    );
    let mut client = HttpClient::connect(&handle.addr().to_string(), Duration::from_secs(2)).unwrap();

    let mut rng = Rng::new(7);
    for trial in 0..16 {
        let x: Vec<f32> = (0..IN_DIM).map(|_| rng.f32() * 2.0 - 1.0).collect();
        // The wire uses shortest-roundtrip decimal, so the server parses
        // back exactly the f32s the reference sees.
        let want = bits(&reference.forward(&x, 1).unwrap());
        let resp = client.request(&infer_req(&x)).unwrap();
        assert_eq!(resp.status, 200, "trial {trial}: {}", resp.body_str());
        assert_eq!(
            output_bits(&resp.body_str()),
            want,
            "trial {trial}: socket output differs from in-process bits"
        );
    }
    assert!(handle.shutdown(Duration::from_secs(5)));
    let _ = std::fs::remove_file(&pack);
}

// ---------------------------------------------------------------- (b)

#[test]
fn full_admission_answers_429_without_blocking_listener() {
    let dir = scratch_dir("admit");
    let pack = write_pack(&dir, "admit", 9);
    // One in-flight slot, and a batcher that parks the first request for
    // ~400ms (big batch, long delay) so the slot is provably occupied.
    let opts = ServeOptions {
        max_inflight: 1,
        default_deadline_ms: 5_000,
        ..ServeOptions::default()
    };
    let handle = spawn(&pack, "admit", 1, opts, server_cfg(64, 400_000));
    let addr = handle.addr().to_string();
    let state = Arc::clone(handle.state());

    let parked = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut c = HttpClient::connect(&addr, Duration::from_secs(2)).unwrap();
            c.set_read_timeout(Duration::from_secs(10)).unwrap();
            c.request(&infer_req(&[0.5; IN_DIM])).unwrap().status
        })
    };
    // Wait until the parked request actually holds the only permit.
    let t0 = Instant::now();
    while state.admission.inflight() != 1 {
        assert!(t0.elapsed() < Duration::from_secs(2), "request never admitted");
        std::thread::sleep(Duration::from_millis(2));
    }

    let mut c2 = HttpClient::connect(&addr, Duration::from_secs(2)).unwrap();
    let rejected = c2.request(&infer_req(&[0.25; IN_DIM])).unwrap();
    assert_eq!(rejected.status, 429, "{}", rejected.body_str());
    assert_eq!(rejected.header("retry-after"), Some("1"));

    // The listener is not wedged: health and metrics answer immediately
    // while the slot is still held.
    assert_eq!(state.admission.inflight(), 1);
    let health = c2.request(&Request::new("GET", "/healthz")).unwrap();
    assert_eq!(health.status, 200);
    let metrics = c2.request(&Request::new("GET", "/metrics")).unwrap();
    assert!(metrics.body_str().contains("serve_rejected_total 1"));

    assert_eq!(parked.join().unwrap(), 200, "parked request must complete");
    assert!(state.admission.rejected_total() >= 1);
    assert!(handle.shutdown(Duration::from_secs(5)));
    let _ = std::fs::remove_file(&pack);
}

// ---------------------------------------------------------------- (c)

#[test]
fn expired_deadline_is_504_and_never_reaches_a_worker() {
    let dir = scratch_dir("deadline");
    let pack = write_pack(&dir, "deadline", 17);
    let handle = spawn(
        &pack,
        "deadline",
        1,
        ServeOptions::default(),
        server_cfg(8, 100),
    );
    let state = Arc::clone(handle.state());
    let mut client = HttpClient::connect(&handle.addr().to_string(), Duration::from_secs(2)).unwrap();

    let admitted_before = state.admission.admitted_total();
    let completed_before = state.router.endpoint("deadline").unwrap().workers.completed_total();
    let req = Request::new("POST", "/v1/infer").json(format!(
        "{{\"input\":{},\"deadline_ms\":0}}",
        json_f32_array(&[1.0; IN_DIM])
    ));
    let resp = client.request(&req).unwrap();
    assert_eq!(resp.status, 504, "{}", resp.body_str());
    // Rejected pre-admission: no permit taken, no batch cut for it.
    assert_eq!(state.admission.admitted_total(), admitted_before);
    assert_eq!(
        state.router.endpoint("deadline").unwrap().workers.completed_total(),
        completed_before
    );

    // The same connection still serves real work afterwards.
    let ok = client.request(&infer_req(&[1.0; IN_DIM])).unwrap();
    assert_eq!(ok.status, 200, "{}", ok.body_str());
    assert!(handle.shutdown(Duration::from_secs(5)));
    let _ = std::fs::remove_file(&pack);
}

// ---------------------------------------------------------------- (d)

#[test]
fn hot_reload_under_fire_serves_only_whole_generations() {
    let dir = scratch_dir("reload");
    let old_pack = write_pack(&dir, "gen-old", 1);
    let new_pack = write_pack(&dir, "gen-new", 2);
    let x = [0.75f32, -0.5, 0.25, 1.0, -1.0, 0.125];
    let want_old = bits(&PackOptions::new(&old_pack).open().unwrap().forward(&x, 1).unwrap());
    let want_new = bits(&PackOptions::new(&new_pack).open().unwrap().forward(&x, 1).unwrap());
    assert_ne!(want_old, want_new, "seeds must give distinguishable packs");

    let router = HotRouter::new(server_cfg(4, 200), 2);
    router.add_pack("m", &old_pack).unwrap();
    let handle = serve("127.0.0.1:0", ServeState::new(router, ServeOptions::default())).unwrap();
    let addr = handle.addr().to_string();
    let state = Arc::clone(handle.state());
    let weak_old: Weak<PackMap> = {
        let ep = state.router.endpoint("m").unwrap();
        Arc::downgrade(&ep.map)
        // `ep` drops here — the test must not keep the old endpoint alive.
    };

    // Hammer the fixed input from several connections while reloading.
    let stop = Arc::new(AtomicBool::new(false));
    let hammers: Vec<_> = (0..3)
        .map(|_| {
            let addr = addr.clone();
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut c = HttpClient::connect(&addr, Duration::from_secs(2)).unwrap();
                let x = [0.75f32, -0.5, 0.25, 1.0, -1.0, 0.125];
                let mut seen = Vec::new();
                while !stop.load(Ordering::Acquire) {
                    let r = c.request(&infer_req(&x)).unwrap();
                    assert_eq!(r.status, 200, "{}", r.body_str());
                    seen.push(output_bits(&r.body_str()));
                }
                seen
            })
        })
        .collect();

    std::thread::sleep(Duration::from_millis(100));
    let mut admin = HttpClient::connect(&addr, Duration::from_secs(2)).unwrap();
    let reload = admin
        .request(&Request::new("POST", "/admin/reload").json(format!(
            "{{\"name\":\"m\",\"path\":\"{}\"}}",
            new_pack.display()
        )))
        .unwrap();
    assert_eq!(reload.status, 200, "{}", reload.body_str());
    assert!(reload.body_str().contains("\"generation\":1"));
    std::thread::sleep(Duration::from_millis(100));
    stop.store(true, Ordering::Release);

    let mut saw_old = 0usize;
    let mut saw_new = 0usize;
    for h in hammers {
        for reply in h.join().unwrap() {
            if reply == want_old {
                saw_old += 1;
            } else if reply == want_new {
                saw_new += 1;
            } else {
                panic!("torn reply: neither old nor new generation bits: {reply:?}");
            }
        }
    }
    assert!(saw_old > 0, "no pre-reload traffic observed");
    // A request after the reload ack must see the new weights (the
    // hammers themselves may or may not have raced past the swap, so
    // `saw_new` is informational only).
    let _ = saw_new;
    let after = admin.request(&infer_req(&x)).unwrap();
    assert_eq!(output_bits(&after.body_str()), want_new);

    // Once nothing holds the old endpoint, its workers drain and the old
    // mapping is released.
    let t0 = Instant::now();
    while weak_old.upgrade().is_some() {
        assert!(
            t0.elapsed() < Duration::from_secs(3),
            "old Arc<PackMap> still alive after reload + drain"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(handle.shutdown(Duration::from_secs(5)));
    let _ = std::fs::remove_file(&old_pack);
    let _ = std::fs::remove_file(&new_pack);
}

// ---------------------------------------------------------------- (e)

#[test]
fn drain_finishes_inflight_and_shutdown_is_clean() {
    let dir = scratch_dir("drain");
    let pack = write_pack(&dir, "drain", 23);
    let handle = spawn(
        &pack,
        "drain",
        1,
        ServeOptions::default(),
        server_cfg(8, 100),
    );
    let addr = handle.addr().to_string();
    let mut client = HttpClient::connect(&addr, Duration::from_secs(2)).unwrap();
    assert_eq!(client.request(&infer_req(&[0.5; IN_DIM])).unwrap().status, 200);

    let drain = client.request(&Request::new("POST", "/admin/drain")).unwrap();
    assert_eq!(drain.status, 200);
    // Draining: inference refused with backoff, health still reports.
    let refused = client.request(&infer_req(&[0.5; IN_DIM])).unwrap();
    assert_eq!(refused.status, 503);
    assert_eq!(refused.header("retry-after"), Some("1"));
    drop(client);
    let mut probe = HttpClient::connect(&addr, Duration::from_secs(2)).unwrap();
    let health = probe.request(&Request::new("GET", "/healthz")).unwrap();
    assert_eq!(health.status, 200);
    assert!(health.body_str().contains("\"draining\""));

    assert!(handle.shutdown(Duration::from_secs(5)), "drain not clean");
    assert!(HttpClient::connect(&addr, Duration::from_millis(300)).is_err());
    let _ = std::fs::remove_file(&pack);
}

/// The real thing: a `repro serve-net` subprocess, killed with SIGTERM
/// mid-life, must drain and exit 0.
#[cfg(unix)]
#[test]
fn sigterm_subprocess_drains_and_exits_zero() {
    use std::process::{Command, Stdio};

    let dir = scratch_dir("sigterm");
    let pack = write_pack(&dir, "sigterm", 31);
    let port_file = dir.join("port");
    let _ = std::fs::remove_file(&port_file);

    let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args([
            "serve-net",
            pack.to_str().unwrap(),
            "--addr",
            "127.0.0.1:0",
            "--port-file",
            port_file.to_str().unwrap(),
            "--workers",
            "1",
            "--drain-timeout-s",
            "10",
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn repro serve-net");

    // Wait for the server to publish its ephemeral port.
    let t0 = Instant::now();
    let addr = loop {
        if let Ok(s) = std::fs::read_to_string(&port_file) {
            if !s.trim().is_empty() {
                break s.trim().to_string();
            }
        }
        if t0.elapsed() > Duration::from_secs(20) {
            let _ = child.kill();
            panic!("serve-net never wrote its port file");
        }
        std::thread::sleep(Duration::from_millis(20));
    };

    let mut client = HttpClient::connect(&addr, Duration::from_secs(2)).unwrap();
    assert_eq!(
        client.request(&Request::new("GET", "/healthz")).unwrap().status,
        200
    );
    assert_eq!(client.request(&infer_req(&[1.0; IN_DIM])).unwrap().status, 200);

    let killed = Command::new("kill")
        .args(["-TERM", &child.id().to_string()])
        .status()
        .expect("run kill");
    assert!(killed.success(), "kill -TERM failed");

    let t0 = Instant::now();
    let status = loop {
        if let Some(s) = child.try_wait().expect("try_wait") {
            break s;
        }
        if t0.elapsed() > Duration::from_secs(15) {
            let _ = child.kill();
            panic!("serve-net did not exit after SIGTERM");
        }
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(status.success(), "SIGTERM drain must exit 0, got {status:?}");
    let _ = std::fs::remove_file(&pack);
}
