//! Nnz-balanced contiguous row sharding.
//!
//! The unit of work for the sparse dot-product kernels is the *stored
//! index*, not the row: low-entropy matrices exhibit exactly the run-length
//! skew (a few dense rows, many nearly-implicit ones) that makes an
//! equal-row split unbalanced. A [`ShardPlan`] partitions `0..rows` into
//! contiguous, disjoint, covering, non-empty shards whose stored-index
//! counts are as equal as the row granularity allows, computed from prefix
//! sums over the format's pointer arrays (`row_ptr`/`omega_ptr` for
//! CER/CSER, `row_ptr` for CSR, uniform `cols` for dense layouts).
//!
//! Plans are computed once per layer (at compression or `from_pack` time)
//! and reused for every product, so planning cost is off the hot path.

use std::ops::Range;

/// A contiguous, disjoint, covering partition of a matrix's rows.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// Shard `i` covers rows `bounds[i]..bounds[i + 1]`; len = shards + 1.
    bounds: Vec<usize>,
    /// Work units (stored indices) per shard.
    work: Vec<u64>,
}

impl ShardPlan {
    /// Build a plan from per-row work prefix sums.
    ///
    /// `prefix.len() == rows + 1`, `prefix[0] == 0`, `prefix[r + 1] -
    /// prefix[r]` is row `r`'s work (stored-index count). The plan has
    /// `min(shards, max(rows, 1))` shards; every shard is non-empty
    /// (except the single shard of a zero-row matrix). Boundaries land on
    /// the rows closest to the ideal `total·i/shards` work marks, so the
    /// heaviest row can at worst make one shard heavy — never two.
    pub fn from_prefix(prefix: &[u64], shards: usize) -> ShardPlan {
        assert!(
            !prefix.is_empty() && prefix[0] == 0,
            "prefix sums must start at 0"
        );
        debug_assert!(prefix.windows(2).all(|w| w[1] >= w[0]), "prefix not monotone");
        let rows = prefix.len() - 1;
        let shards = shards.max(1).min(rows.max(1));
        let total = prefix[rows] as u128;
        let mut bounds = Vec::with_capacity(shards + 1);
        bounds.push(0usize);
        for i in 1..shards {
            let target = (total * i as u128 / shards as u128) as u64;
            // First row boundary at or past the ideal work mark, clamped so
            // this shard and every remaining one stay non-empty.
            let r = prefix.partition_point(|&p| p < target);
            let lo = bounds[i - 1] + 1;
            let hi = rows - (shards - i);
            bounds.push(r.clamp(lo, hi));
        }
        bounds.push(rows);
        let work = bounds
            .windows(2)
            .map(|w| prefix[w[1]] - prefix[w[0]])
            .collect();
        ShardPlan { bounds, work }
    }

    /// [`ShardPlan::from_prefix`] with a minimum-work floor per shard:
    /// the shard count is capped at `total_work / min_shard_work` (at
    /// least 1), so a small layer is split across fewer lanes — or run
    /// serially — instead of being diced into shards too small to fill a
    /// kernel tile. `min_shard_work == 0` disables the floor and is
    /// exactly [`ShardPlan::from_prefix`].
    pub fn from_prefix_granular(prefix: &[u64], shards: usize, min_shard_work: u64) -> ShardPlan {
        assert!(
            !prefix.is_empty() && prefix[0] == 0,
            "prefix sums must start at 0"
        );
        let total = *prefix.last().expect("prefix non-empty");
        let cap = if min_shard_work == 0 {
            shards
        } else {
            ((total / min_shard_work) as usize).max(1)
        };
        ShardPlan::from_prefix(prefix, shards.min(cap))
    }

    /// Plan for uniform per-row cost (dense layouts: every row costs
    /// `cost_per_row` = cols).
    pub fn uniform(rows: usize, cost_per_row: u64, shards: usize) -> ShardPlan {
        let prefix: Vec<u64> = (0..=rows as u64).map(|r| r * cost_per_row).collect();
        ShardPlan::from_prefix(&prefix, shards)
    }

    /// Total rows covered by the plan.
    pub fn rows(&self) -> usize {
        *self.bounds.last().expect("bounds non-empty")
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Row range of shard `i`.
    pub fn shard(&self, i: usize) -> Range<usize> {
        self.bounds[i]..self.bounds[i + 1]
    }

    /// Iterate over the shard row ranges, in order.
    pub fn shards(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.shard_count()).map(|i| self.shard(i))
    }

    /// Work units (stored indices) assigned to shard `i`.
    pub fn work(&self, i: usize) -> u64 {
        self.work[i]
    }

    /// Total work units across all shards.
    pub fn total_work(&self) -> u64 {
        self.work.iter().sum()
    }

    /// Heaviest shard's work units — the parallel critical path, which is
    /// what the cost model's sharded time estimate scales by.
    pub fn max_work(&self) -> u64 {
        self.work.iter().copied().max().unwrap_or(0)
    }

    /// Heaviest shard's work relative to the ideal equal split (1.0 =
    /// perfectly balanced). A plain equal-row split of a skewed matrix
    /// scores close to `shard_count()`.
    pub fn max_imbalance(&self) -> f64 {
        let total = self.total_work();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / self.shard_count() as f64;
        self.max_work() as f64 / mean
    }

    /// Human-readable balance report: per-shard row ranges and nnz counts.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} shard(s) over {} rows, {} nnz (imbalance x{:.2}):",
            self.shard_count(),
            self.rows(),
            self.total_work(),
            self.max_imbalance()
        );
        for i in 0..self.shard_count() {
            let r = self.shard(i);
            s.push_str(&format!(" [{}..{}) nnz {}", r.start, r.end, self.work(i)));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_invariants(plan: &ShardPlan, rows: usize, requested: usize, prefix: &[u64]) {
        assert_eq!(plan.rows(), rows);
        assert_eq!(plan.shard_count(), requested.max(1).min(rows.max(1)));
        let mut covered = 0usize;
        for (i, r) in plan.shards().enumerate() {
            assert_eq!(r.start, covered, "shards must be contiguous");
            if rows > 0 {
                assert!(!r.is_empty(), "shard {i} empty");
            }
            assert_eq!(plan.work(i), prefix[r.end] - prefix[r.start]);
            covered = r.end;
        }
        assert_eq!(covered, rows, "shards must cover all rows");
        assert_eq!(plan.total_work(), *prefix.last().unwrap());
    }

    #[test]
    fn uniform_costs_split_evenly() {
        for rows in [1usize, 2, 5, 64, 100] {
            for shards in [1usize, 2, 4, 7, 100] {
                let prefix: Vec<u64> = (0..=rows as u64).collect();
                let plan = ShardPlan::from_prefix(&prefix, shards);
                check_invariants(&plan, rows, shards, &prefix);
                let per = rows / plan.shard_count();
                for r in plan.shards() {
                    assert!(r.len() >= per, "uniform split should not starve a shard");
                    assert!(r.len() <= per + 1, "uniform split should be near-even");
                }
            }
        }
    }

    #[test]
    fn skewed_work_balances_by_nnz_not_rows() {
        // Row 0 carries 900 of 1000 units; rows 1..=9 carry ~11 each.
        let mut prefix = vec![0u64, 900];
        for r in 1..10u64 {
            prefix.push(900 + r * 11);
        }
        let rows = prefix.len() - 1;
        let plan = ShardPlan::from_prefix(&prefix, 4);
        check_invariants(&plan, rows, 4, &prefix);
        // The heavy row must sit alone in its shard; the other rows share.
        assert_eq!(plan.shard(0), 0..1);
        assert_eq!(plan.work(0), 900);
        // An equal-row split would put heavy+light rows together: imbalance
        // there is ~3.6x; by-nnz it is bounded by the single heavy row.
        let by_rows = ShardPlan::uniform(rows, 1, 4);
        assert!(plan.max_imbalance() <= by_rows.shard_count() as f64);
        assert!(plan.summary().contains("nnz 900"));
    }

    #[test]
    fn all_work_in_one_row_degenerates_gracefully() {
        let prefix = vec![0u64, 0, 0, 50, 50, 50];
        let plan = ShardPlan::from_prefix(&prefix, 3);
        check_invariants(&plan, 5, 3, &prefix);
        assert_eq!(plan.total_work(), 50);
    }

    #[test]
    fn fewer_rows_than_shards() {
        let prefix = vec![0u64, 4, 9];
        let plan = ShardPlan::from_prefix(&prefix, 7);
        check_invariants(&plan, 2, 7, &prefix);
        assert_eq!(plan.shard_count(), 2);
    }

    #[test]
    fn granular_floor_caps_shard_count() {
        // 16 rows × 10 work each = 160 total.
        let prefix: Vec<u64> = (0..=16u64).map(|r| r * 10).collect();
        // Floor 50 → at most 3 shards even when 8 are requested.
        let plan = ShardPlan::from_prefix_granular(&prefix, 8, 50);
        assert_eq!(plan.shard_count(), 3);
        check_invariants(&plan, 16, 3, &prefix);
        // Floor larger than the total work → serial.
        assert_eq!(ShardPlan::from_prefix_granular(&prefix, 8, 1000).shard_count(), 1);
        // Zero floor → identical to the plain plan.
        assert_eq!(
            ShardPlan::from_prefix_granular(&prefix, 8, 0),
            ShardPlan::from_prefix(&prefix, 8)
        );
        // A generous floor never *adds* shards past the request.
        assert_eq!(ShardPlan::from_prefix_granular(&prefix, 2, 1).shard_count(), 2);
    }

    #[test]
    fn zero_rows_single_empty_shard() {
        let plan = ShardPlan::from_prefix(&[0], 4);
        assert_eq!(plan.shard_count(), 1);
        assert_eq!(plan.rows(), 0);
        assert!(plan.shard(0).is_empty());
        assert!((plan.max_imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_total_work_falls_back_to_row_split() {
        let prefix = vec![0u64; 9]; // 8 rows, no stored indices at all
        let plan = ShardPlan::from_prefix(&prefix, 4);
        check_invariants(&plan, 8, 4, &prefix);
    }
}
