//! Compressed Sparse Row — the sparse baseline (§III-A "Sparse format").
//!
//! Stores the non-zero values in row-major order (`values`), their column
//! indices (`col_idx`) and row pointers into those arrays (`row_ptr`).

use super::{ColIndices, Dense, IndexWidth, MatrixFormat, StorageBreakdown, StoragePart, VALUE_BITS};

/// CSR matrix with minimal-width column indices.
#[derive(Clone, Debug)]
pub struct Csr {
    rows: usize,
    cols: usize,
    /// Non-zero values in row-major scan order (the paper's `W`).
    pub values: Vec<f32>,
    /// Column index of each value.
    pub col_idx: ColIndices,
    /// `row_ptr[r]..row_ptr[r+1]` indexes `values`/`col_idx` for row `r`.
    pub row_ptr: Vec<u32>,
}

impl Csr {
    /// Row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Convert from dense, O(N).
    pub fn from_dense(m: &Dense) -> Csr {
        let (rows, cols) = (m.rows(), m.cols());
        let mut values = Vec::new();
        let mut cols_v: Vec<usize> = Vec::new();
        let mut row_ptr = Vec::with_capacity(rows + 1);
        row_ptr.push(0u32);
        for r in 0..rows {
            for (c, &v) in m.row(r).iter().enumerate() {
                if v != 0.0 {
                    values.push(v);
                    cols_v.push(c);
                }
            }
            row_ptr.push(values.len() as u32);
        }
        Csr {
            rows,
            cols,
            values,
            col_idx: ColIndices::pack(&cols_v, cols),
            row_ptr,
        }
    }

    /// Number of stored (non-zero) elements.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Accounted width of the row-pointer array (max value is nnz).
    pub fn row_ptr_width(&self) -> IndexWidth {
        IndexWidth::minimal(self.nnz())
    }
}

impl MatrixFormat for Csr {
    fn name(&self) -> &'static str {
        "CSR"
    }
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }

    fn to_dense(&self) -> Dense {
        let mut out = Dense::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (s, e) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            for i in s..e {
                out.set(r, self.col_idx.get(i), self.values[i]);
            }
        }
        out
    }

    fn storage(&self) -> StorageBreakdown {
        StorageBreakdown {
            parts: vec![
                StoragePart {
                    name: "Omega",
                    entries: self.values.len() as u64,
                    bits_per_entry: VALUE_BITS,
                },
                StoragePart {
                    name: "colI",
                    entries: self.col_idx.len() as u64,
                    bits_per_entry: self.col_idx.width().bits(),
                },
                StoragePart {
                    name: "rowPtr",
                    entries: self.row_ptr.len() as u64,
                    bits_per_entry: self.row_ptr_width().bits(),
                },
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::paper_example_matrix;

    #[test]
    fn paper_example_arrays() {
        // §III-A gives the exact CSR arrays of the 5×12 running example.
        let m = paper_example_matrix();
        let csr = Csr::from_dense(&m);
        assert_eq!(
            csr.values,
            vec![
                3., 2., 4., 2., 3., 4., 4., 4., 4., 4., 4., 4., 4., 4., 3., 4., 4., 2., 4., 4.,
                4., 3., 4., 4., 4., 4., 4., 4.
            ]
        );
        assert_eq!(
            csr.col_idx.to_vec(),
            vec![
                1, 3, 4, 7, 8, 9, 11, 0, 1, 5, 8, 9, 11, 0, 2, 3, 7, 9, 3, 4, 5, 7, 8, 9, 1, 2,
                5, 7
            ]
        );
        assert_eq!(csr.row_ptr, vec![0, 7, 13, 18, 24, 28]);
        // "62 entries" (§III-A): 28 values + 28 indices + 6 pointers.
        let entries: u64 = csr.storage().parts.iter().map(|p| p.entries).sum();
        assert_eq!(entries, 62);
    }

    #[test]
    fn roundtrip() {
        let m = paper_example_matrix();
        assert_eq!(Csr::from_dense(&m).to_dense(), m);
    }

    #[test]
    fn empty_and_full_rows() {
        let m = Dense::from_rows(&[
            vec![0.0, 0.0, 0.0],
            vec![1.0, 2.0, 3.0],
            vec![0.0, 5.0, 0.0],
        ]);
        let csr = Csr::from_dense(&m);
        assert_eq!(csr.nnz(), 4);
        assert_eq!(csr.row_ptr, vec![0, 0, 3, 4]);
        assert_eq!(csr.to_dense(), m);
    }

    #[test]
    fn all_zero_matrix() {
        let m = Dense::zeros(4, 7);
        let csr = Csr::from_dense(&m);
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.to_dense(), m);
    }

    #[test]
    fn storage_matches_eq3_shape() {
        // Eq. (3): per-element storage (1-p0)(b_Omega + b_I) + b_I/n (+ptr rounding).
        let m = paper_example_matrix();
        let csr = Csr::from_dense(&m);
        let bits = csr.storage().total_bits();
        // 28 values * 32 + 28 idx * 8 + 6 ptr * 8
        assert_eq!(bits, 28 * 32 + 28 * 8 + 6 * 8);
    }
}
