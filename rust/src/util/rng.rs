//! Deterministic, dependency-free PRNG (xoshiro256++ seeded via SplitMix64).
//!
//! All experiments in the harness are parameterized by an explicit `u64`
//! seed so that every number in EXPERIMENTS.md can be regenerated exactly.

/// SplitMix64 step — used to expand a single `u64` seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator.
///
/// Chosen over a crate dependency to keep the core library self-contained
/// and the experiment outputs stable across dependency upgrades.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a sub-experiment (`label` mixes the
    /// stream id into the seed so parallel experiments never share state).
    pub fn fork(&mut self, label: u64) -> Rng {
        Rng::new(self.next_u64() ^ label.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → uniform double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's rejection-free-ish method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple, exact
    /// enough for weight synthesis).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            // 10k expected; allow ±10%.
            assert!((9_000..11_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_has_unit_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
