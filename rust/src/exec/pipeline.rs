//! One-dispatch-per-forward pipelined execution.
//!
//! PR 2 parallelized each layer product with its own pool dispatch: one
//! `run_scoped` fan-out plus a full join barrier per layer. For a deep
//! network at small batch sizes that round trip — wake the workers, run a
//! sub-millisecond shard, park the workers, repeat — dominates the layer
//! compute itself. A [`Pipeline`] job instead submits the *whole layer
//! sequence* to the persistent pool once: every execution lane loops over
//! the steps, and a lightweight generation-counting [`WaveBarrier`]
//! between steps replaces the dispatch/join round trip. Workers never
//! park between layers of one forward pass.
//!
//! **Determinism:** the pipeline only changes *when* shard kernels run,
//! never what they compute — each lane executes the same `ShardPlan`
//! shards with the same serial inner loops, so output stays bit-identical
//! to both the serial path and the per-layer-dispatch path.
//!
//! **Allocation:** `Pipeline::run` goes through
//! [`ThreadPool::run_lanes`], which dispatches without heap allocation;
//! together with the engine's activation arena this makes the
//! steady-state fused forward pass allocation-free (asserted by
//! `tests/alloc_free.rs`).

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use super::ThreadPool;

/// A reusable generation-counting rendezvous barrier.
///
/// Three-stage waiting: spin (keeps the inter-layer gap in the tens of
/// nanoseconds when lanes are balanced — the `ShardPlan`'s job), then
/// `yield_now`, then **park on a condvar** — so an oversubscribed lane
/// count (`--threads` past the core count) degrades to sleeping waiters
/// instead of a yield storm that burns exactly the cores the straggler
/// lanes need. The release path always bumps the generation under the
/// park lock before notifying, so a parked waiter can never miss a wave.
#[derive(Debug, Default)]
pub struct WaveBarrier {
    arrived: AtomicUsize,
    gen: AtomicUsize,
    park: Mutex<()>,
    unpark: Condvar,
}

impl WaveBarrier {
    pub fn new() -> WaveBarrier {
        WaveBarrier::default()
    }

    /// Block until `parties` threads (this one included) have called
    /// `wait` in the current generation. Every caller of one generation
    /// must pass the same `parties`.
    pub fn wait(&self, parties: usize) {
        debug_assert!(parties >= 1);
        let gen = self.gen.load(Ordering::Acquire);
        if self.arrived.fetch_add(1, Ordering::AcqRel) + 1 == parties {
            // Last arriver: reset the count *before* releasing the wave so
            // an early next-generation arriver can never observe a stale
            // count (the release on `gen` orders the reset for waiters).
            self.arrived.store(0, Ordering::Relaxed);
            // Bump under the park lock: a waiter decides to sleep only
            // while holding it, so the bump+notify can't slip between its
            // last check and its wait (no lost wakeup).
            let guard = self.park.lock().expect("barrier park lock");
            self.gen.fetch_add(1, Ordering::Release);
            drop(guard);
            self.unpark.notify_all();
            return;
        }
        let mut spins = 0u32;
        while self.gen.load(Ordering::Acquire) == gen {
            spins = spins.wrapping_add(1);
            if spins < 128 {
                std::hint::spin_loop();
            } else if spins < 512 {
                std::thread::yield_now();
            } else {
                // Stage 3: park until the wave is released.
                let mut guard = self.park.lock().expect("barrier park lock");
                while self.gen.load(Ordering::Acquire) == gen {
                    guard = self.unpark.wait(guard).expect("barrier park lock");
                }
                return;
            }
        }
    }
}

/// A pipelined multi-step job: the exec plane's unit of *whole-forward*
/// work, vs. [`ThreadPool::run_scoped`]'s per-product shard fan-out.
///
/// The barrier is owned (not per-run stack state) so one engine reuses it
/// across every forward pass; generation counting makes reuse safe.
#[derive(Debug, Default)]
pub struct Pipeline {
    barrier: WaveBarrier,
}

impl Pipeline {
    pub fn new() -> Pipeline {
        Pipeline::default()
    }

    /// Execute `steps` dependent stages in **one** pool dispatch.
    ///
    /// `step(s, lane)` is called for every `s in 0..steps` on every `lane
    /// in 0..lanes`, with a barrier between consecutive steps: no lane
    /// starts step `s + 1` until every lane has finished step `s` (so step
    /// `s + 1` may read anything step `s` wrote). Within a step, lanes run
    /// concurrently and must write disjoint data — the engine hands each
    /// lane its own `ShardPlan` rows.
    ///
    /// `lanes` is clamped to the pool's [`ThreadPool::lane_limit`]; with
    /// no pool (or a single lane) the steps run serially on the caller,
    /// which is exactly the engine's `--threads 1` path.
    ///
    /// A panic inside a step poisons the pipeline: remaining steps are
    /// skipped (lanes keep arriving at the barriers so every lane drains),
    /// and the first payload is re-raised here.
    pub fn run(
        &self,
        pool: Option<&ThreadPool>,
        lanes: usize,
        steps: usize,
        step: &(dyn Fn(usize, usize) + Sync),
    ) {
        if steps == 0 {
            return;
        }
        let lanes = match pool {
            Some(p) => lanes.clamp(1, p.lane_limit()),
            None => 1,
        };
        let (Some(pool), true) = (pool, lanes > 1) else {
            for s in 0..steps {
                step(s, 0);
            }
            return;
        };
        let poisoned = AtomicBool::new(false);
        let payload: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
        let barrier = &self.barrier;
        pool.run_lanes(lanes, &|lane| {
            for s in 0..steps {
                if s > 0 {
                    barrier.wait(lanes);
                }
                if poisoned.load(Ordering::Acquire) {
                    continue;
                }
                if let Err(p) = catch_unwind(AssertUnwindSafe(|| step(s, lane))) {
                    poisoned.store(true, Ordering::Release);
                    payload
                        .lock()
                        .expect("pipeline panic slot")
                        .get_or_insert(p);
                }
            }
        });
        if let Some(p) = payload.lock().expect("pipeline panic slot").take() {
            resume_unwind(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn serial_pipeline_runs_steps_in_order() {
        let p = Pipeline::new();
        let log = Mutex::new(Vec::new());
        p.run(None, 4, 3, &|s, lane| {
            assert_eq!(lane, 0);
            log.lock().unwrap().push(s);
        });
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn steps_are_barrier_separated() {
        // Every lane must see the *complete* previous step: lane sums of a
        // shared counter only match if no lane raced ahead of the barrier.
        let pool = ThreadPool::new(3);
        let p = Pipeline::new();
        let lanes = pool.lane_limit();
        let steps = 16usize;
        let counter = AtomicU64::new(0);
        let bad = AtomicUsize::new(0);
        p.run(Some(&pool), lanes, steps, &|s, _lane| {
            // At entry to step s, all lanes have finished steps 0..s:
            // exactly lanes * s increments must be visible.
            if counter.load(Ordering::SeqCst) < (lanes * s) as u64 {
                bad.fetch_add(1, Ordering::Relaxed);
            }
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(bad.load(Ordering::Relaxed), 0);
        assert_eq!(counter.load(Ordering::SeqCst), (lanes * steps) as u64);
    }

    #[test]
    fn pipeline_reuse_across_runs() {
        let pool = ThreadPool::new(2);
        let p = Pipeline::new();
        let lanes = pool.lane_limit();
        for _ in 0..20 {
            let hits = AtomicUsize::new(0);
            p.run(Some(&pool), lanes, 5, &|_, _| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), lanes * 5);
        }
    }

    #[test]
    fn lane_count_clamps_to_pool() {
        let pool = ThreadPool::new(1);
        let p = Pipeline::new();
        let seen = Mutex::new(std::collections::BTreeSet::new());
        p.run(Some(&pool), 64, 2, &|_, lane| {
            seen.lock().unwrap().insert(lane);
        });
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), pool.lane_limit());
        assert!(seen.iter().all(|&l| l < pool.lane_limit()));
    }

    #[test]
    fn panic_poisons_but_drains_and_propagates() {
        let pool = ThreadPool::new(2);
        let p = Pipeline::new();
        let after = AtomicUsize::new(0);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            p.run(Some(&pool), 3, 4, &|s, lane| {
                if s == 1 && lane == 0 {
                    panic!("step boom");
                }
                if s > 1 {
                    after.fetch_add(1, Ordering::Relaxed);
                }
            });
        }));
        assert!(r.is_err());
        // Poison halts later steps on every lane (at most the racing
        // step-1 stragglers slip through, never steps 2..).
        assert!(after.load(Ordering::Relaxed) <= 3 * 2);
        // And the pipeline + pool stay usable.
        let ok = AtomicUsize::new(0);
        p.run(Some(&pool), 3, 2, &|_, _| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 6);
    }
}
