//! The inference engine: a stack of compressed layers (each in its
//! selected representation) with two execution backends:
//!
//! * **Native** — the Rust CER/CSER/CSR/dense kernels of this crate; the
//!   paper's contribution on the serving path.
//! * **Xla** — the AOT-compiled artifacts (`model_dense.hlo.txt` /
//!   `model_cser.hlo.txt`) executed through PJRT; the L1/L2 layers of the
//!   stack, with identical numerics (asserted by the e2e example and the
//!   integration tests).
//!
//! Batch layout trick: a row-major (batch × n) activation buffer *is* a
//! column-major (n × batch) matrix, so the native path feeds
//! `matmul_colmajor` without any transpose copies.


use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::selector::{select_format, Objective};
use crate::costmodel::{EnergyModel, TimeModel};
use crate::exec::{ExecPlane, ShardPlan};
use crate::formats::{Dense, FormatKind};
use crate::kernels::AnyMatrix;
use crate::pack::{self, LayerView, Manifest, Pack};
use crate::runtime::{Arg, MlpArtifacts, XlaRuntime};

/// Which execution backend the engine uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Native Rust kernels over the selected formats.
    Native,
    /// PJRT execution of the AOT CSER-kernel artifact.
    XlaCser,
    /// PJRT execution of the AOT dense artifact (float weights).
    XlaDense,
}

/// One layer of the engine.
#[derive(Clone, Debug)]
pub struct EngineLayer {
    pub name: String,
    pub matrix: AnyMatrix,
    pub bias: Vec<f32>,
}

/// Derive a (codes, omega) pair from a quantized dense matrix with omega
/// ascending — the convention shared with `aot.codes_from_quantized`.
pub fn to_codes(m: &Dense) -> (Vec<i32>, Vec<f32>) {
    let mut omega: Vec<f32> = m.data().to_vec();
    omega.sort_by(|a, b| a.partial_cmp(b).expect("no NaN"));
    omega.dedup();
    let codes = m
        .data()
        .iter()
        .map(|v| {
            omega
                .binary_search_by(|p| p.partial_cmp(v).unwrap())
                .expect("value in codebook") as i32
        })
        .collect();
    (codes, omega)
}

/// XLA backend state (owned by the engine; not Send — construct the engine
/// inside its serving thread).
struct XlaState {
    /// Keeps the PJRT client (and its executable cache) alive for `exe`.
    #[allow(dead_code)]
    runtime: XlaRuntime,
    exe: std::rc::Rc<crate::runtime::Executable>,
    /// Fixed (weight) arguments appended after the input batch.
    fixed_args: Vec<Arg>,
    batch: usize,
}

/// The inference engine.
pub struct Engine {
    pub layers: Vec<EngineLayer>,
    backend: Backend,
    xla: Option<XlaState>,
    /// Scratch activation buffers (reused across forwards).
    scratch: Vec<Vec<f32>>,
    /// Multi-core execution plane (serial unless [`Engine::set_threads`]).
    exec: ExecPlane,
    /// One nnz-balanced plan per layer, computed once when the plane is
    /// configured (empty when serial).
    plans: Vec<ShardPlan>,
}

impl Engine {
    /// Build a native engine from quantized layers, auto-selecting each
    /// layer's format for `objective`.
    pub fn native_auto(
        layers: Vec<(String, Dense, Vec<f32>)>,
        energy: &EnergyModel,
        time: &TimeModel,
        objective: Objective,
    ) -> Engine {
        let layers = layers
            .into_iter()
            .map(|(name, m, bias)| {
                let (kind, _) = select_format(&m, energy, time, objective);
                EngineLayer {
                    name,
                    matrix: AnyMatrix::encode(kind, &m),
                    bias,
                }
            })
            .collect();
        Engine {
            layers,
            backend: Backend::Native,
            xla: None,
            scratch: Vec::new(),
            exec: ExecPlane::serial(),
            plans: Vec::new(),
        }
    }

    /// Build a native engine with an explicit format for every layer.
    pub fn native_fixed(layers: Vec<(String, Dense, Vec<f32>)>, kind: FormatKind) -> Engine {
        let layers = layers
            .into_iter()
            .map(|(name, m, bias)| EngineLayer {
                name,
                matrix: AnyMatrix::encode(kind, &m),
                bias,
            })
            .collect();
        Engine {
            layers,
            backend: Backend::Native,
            xla: None,
            scratch: Vec::new(),
            exec: ExecPlane::serial(),
            plans: Vec::new(),
        }
    }

    /// Build an engine over the e2e artifacts.
    ///
    /// `Backend::Native` encodes the quantized weights with auto-selection;
    /// the XLA backends compile the corresponding HLO artifact and bind the
    /// weight arguments once.
    pub fn from_artifacts(
        art: &MlpArtifacts,
        backend: Backend,
        objective: Objective,
    ) -> Result<Engine> {
        let named = |quantized: bool| -> Vec<(String, Dense, Vec<f32>)> {
            art.layers
                .iter()
                .enumerate()
                .map(|(i, l)| {
                    (
                        format!("fc{i}"),
                        if quantized {
                            l.quantized.clone()
                        } else {
                            l.weights.clone()
                        },
                        l.bias.clone(),
                    )
                })
                .collect()
        };
        match backend {
            Backend::Native => Ok(Engine::native_auto(
                named(true),
                &EnergyModel::table_i(),
                &TimeModel::default_model(),
                objective,
            )),
            Backend::XlaDense | Backend::XlaCser => {
                let mut runtime = XlaRuntime::cpu()?;
                let (path, fixed_args) = if backend == Backend::XlaDense {
                    let mut args = Vec::new();
                    for l in &art.layers {
                        let (m, n) = (l.weights.rows(), l.weights.cols());
                        args.push(Arg::f32(l.weights.data().to_vec(), &[m, n]));
                        args.push(Arg::f32(l.bias.clone(), &[m]));
                    }
                    (art.dense_hlo.clone(), args)
                } else {
                    let mut args = Vec::new();
                    for l in &art.layers {
                        let (m, n) = (l.quantized.rows(), l.quantized.cols());
                        let (codes, omega) = to_codes(&l.quantized);
                        args.push(Arg::i32(codes, &[m, n]));
                        args.push(Arg::f32(omega.clone(), &[omega.len()]));
                        args.push(Arg::f32(l.bias.clone(), &[m]));
                    }
                    (art.cser_hlo.clone(), args)
                };
                let exe = runtime
                    .load(&path)
                    .with_context(|| format!("loading {}", path.display()))?;
                Ok(Engine {
                    layers: named(backend == Backend::XlaCser)
                        .into_iter()
                        .map(|(name, m, bias)| EngineLayer {
                            name,
                            matrix: AnyMatrix::Dense(m),
                            bias,
                        })
                        .collect(),
                    backend,
                    xla: Some(XlaState {
                        runtime,
                        exe,
                        fixed_args,
                        batch: art.batch,
                    }),
                    scratch: Vec::new(),
                    exec: ExecPlane::serial(),
                    plans: Vec::new(),
                })
            }
        }
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Configure the multi-core execution plane: `threads <= 1` restores
    /// the exact serial code path; otherwise a persistent pool of
    /// `threads - 1` workers is (re)built and one nnz-balanced
    /// [`ShardPlan`] per layer is computed here, once — never on the hot
    /// path. Forward results are bit-identical at every thread count.
    pub fn set_threads(&mut self, threads: usize) {
        self.exec = ExecPlane::with_threads(threads);
        self.plans = if self.exec.is_parallel() {
            self.layers
                .iter()
                .map(|l| l.matrix.shard_plan(self.exec.threads()))
                .collect()
        } else {
            Vec::new()
        };
    }

    /// Builder form of [`Engine::set_threads`].
    pub fn with_threads(mut self, threads: usize) -> Engine {
        self.set_threads(threads);
        self
    }

    /// Execution lanes in use (1 = serial).
    pub fn threads(&self) -> usize {
        self.exec.threads()
    }

    /// The per-layer shard plans (empty when serial) — balance is
    /// observable via [`ShardPlan::summary`].
    pub fn shard_plans(&self) -> &[ShardPlan] {
        &self.plans
    }

    /// Input dimensionality.
    pub fn in_dim(&self) -> usize {
        self.layers[0].matrix.cols()
    }

    /// Output dimensionality.
    pub fn out_dim(&self) -> usize {
        self.layers.last().unwrap().matrix.rows()
    }

    /// Static batch size required by the XLA backends (None = any).
    pub fn required_batch(&self) -> Option<usize> {
        self.xla.as_ref().map(|x| x.batch)
    }

    /// Forward a batch: `x` row-major (batch × in_dim) → logits row-major
    /// (batch × out_dim). ReLU between layers, none after the last.
    pub fn forward(&mut self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        assert_eq!(x.len(), batch * self.in_dim(), "input shape");
        match self.backend {
            Backend::Native => Ok(self.forward_native(x, batch)),
            Backend::XlaDense | Backend::XlaCser => {
                let st = self.xla.as_mut().expect("xla state");
                assert_eq!(
                    batch, st.batch,
                    "XLA backend lowered for batch {}, got {batch}",
                    st.batch
                );
                let mut args = vec![Arg::f32(x.to_vec(), &[batch, x.len() / batch])];
                args.extend(st.fixed_args.iter().cloned());
                st.exe.run_f32(&args)
            }
        }
    }

    fn forward_native(&mut self, x: &[f32], batch: usize) -> Vec<f32> {
        // Row-major (batch × n) ≡ column-major (n × batch): no transposes.
        self.scratch.resize(self.layers.len(), Vec::new());
        let mut cur: Vec<f32> = x.to_vec();
        let last = self.layers.len() - 1;
        for (i, layer) in self.layers.iter().enumerate() {
            let (m, _n) = (layer.matrix.rows(), layer.matrix.cols());
            let out = &mut self.scratch[i];
            out.clear();
            out.resize(m * batch, 0.0);
            match (self.exec.pool(), self.plans.get(i)) {
                (Some(pool), Some(plan)) => {
                    layer.matrix.matmul_colmajor_sharded(&cur, out, batch, plan, pool)
                }
                _ => layer.matrix.matmul_colmajor(&cur, out, batch),
            }
            for s in 0..batch {
                let col = &mut out[s * m..(s + 1) * m];
                for (v, b) in col.iter_mut().zip(&layer.bias) {
                    *v += b;
                    if i != last && *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            std::mem::swap(&mut cur, out);
        }
        cur
    }

    /// Classify a batch: argmax logits per sample.
    pub fn classify(&mut self, x: &[f32], batch: usize) -> Result<Vec<usize>> {
        let logits = self.forward(x, batch)?;
        let out = self.out_dim();
        Ok((0..batch)
            .map(|s| {
                let row = &logits[s * out..(s + 1) * out];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            })
            .collect())
    }

    /// Snapshot the engine's layers (selected formats, biases, measured
    /// provenance) into an in-memory [`Pack`]. Clones the layers — use
    /// [`Engine::save_pack`] to serialize without the copy.
    pub fn to_pack(&self, network: &str, rationale: &str) -> Pack {
        Pack::from_layers(
            network,
            rationale,
            self.layers
                .iter()
                .map(|l| (l.name.clone(), l.matrix.clone(), l.bias.clone()))
                .collect(),
        )
    }

    /// Serialize the engine to a `.cerpack` artifact, borrowing the
    /// layers (no clone of the network). Returns the file size in bytes
    /// and the manifest as written (with measured on-disk byte counts
    /// filled in).
    pub fn save_pack(
        &self,
        path: &Path,
        network: &str,
        rationale: &str,
    ) -> Result<(u64, Manifest)> {
        let views: Vec<LayerView<'_>> = self
            .layers
            .iter()
            .map(|l| LayerView {
                name: &l.name,
                matrix: &l.matrix,
                bias: &l.bias,
            })
            .collect();
        let manifest = pack::build_manifest(network, rationale, &views);
        let (bytes, manifest) = pack::serialize(&manifest, &views);
        std::fs::write(path, &bytes).with_context(|| format!("writing {}", path.display()))?;
        Ok((bytes.len() as u64, manifest))
    }

    /// Cold-start a native engine from a `.cerpack` artifact: layers come
    /// back in their stored (already-selected) formats — no pruning,
    /// clustering, re-encoding or format selection runs.
    pub fn from_pack(path: &Path) -> Result<Engine> {
        let pack = Pack::read(path).with_context(|| format!("loading {}", path.display()))?;
        Ok(Engine::from_pack_data(pack))
    }

    /// Build a native engine from an already-decoded [`Pack`].
    pub fn from_pack_data(pack: Pack) -> Engine {
        Engine {
            layers: pack
                .layers
                .into_iter()
                .map(|l| EngineLayer {
                    name: l.name,
                    matrix: l.matrix,
                    bias: l.bias,
                })
                .collect(),
            backend: Backend::Native,
            xla: None,
            scratch: Vec::new(),
            exec: ExecPlane::serial(),
            plans: Vec::new(),
        }
    }

    /// Total storage of the engine's weight matrices (bits).
    pub fn storage_bits(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.matrix.storage().total_bits())
            .sum()
    }

    /// Formats in use, per layer.
    pub fn formats(&self) -> Vec<FormatKind> {
        self.layers.iter().map(|l| l.matrix.kind()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tiny_layers(seed: u64) -> Vec<(String, Dense, Vec<f32>)> {
        let mut rng = Rng::new(seed);
        let grid = [-0.4f32, -0.2, 0.0, 0.2, 0.4];
        let mk = |rng: &mut Rng, m: usize, n: usize| {
            Dense::from_vec(
                m,
                n,
                (0..m * n).map(|_| grid[rng.below(5)]).collect(),
            )
        };
        vec![
            ("fc0".into(), mk(&mut rng, 8, 12), vec![0.1; 8]),
            ("fc1".into(), mk(&mut rng, 5, 8), vec![-0.1; 5]),
            ("fc2".into(), mk(&mut rng, 3, 5), vec![0.0; 3]),
        ]
    }

    /// Oracle forward in f64.
    fn oracle_forward(layers: &[(String, Dense, Vec<f32>)], x: &[f32], batch: usize) -> Vec<f32> {
        let mut cur: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let last = layers.len() - 1;
        for (i, (_, w, b)) in layers.iter().enumerate() {
            let (m, n) = (w.rows(), w.cols());
            let mut next = vec![0.0f64; batch * m];
            for s in 0..batch {
                for r in 0..m {
                    let mut acc = b[r] as f64;
                    for c in 0..n {
                        acc += w.get(r, c) as f64 * cur[s * n + c];
                    }
                    next[s * m + r] = if i != last && acc < 0.0 { 0.0 } else { acc };
                }
            }
            cur = next;
        }
        cur.into_iter().map(|v| v as f32).collect()
    }

    #[test]
    fn native_forward_matches_oracle_all_formats() {
        let layers = tiny_layers(1);
        let mut rng = Rng::new(2);
        let batch = 4;
        let x: Vec<f32> = (0..batch * 12).map(|_| rng.f32() - 0.5).collect();
        let want = oracle_forward(&layers, &x, batch);
        for kind in FormatKind::ALL {
            let mut e = Engine::native_fixed(layers.clone(), kind);
            let got = e.forward(&x, batch).unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "{kind:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn auto_engine_picks_formats_and_matches() {
        let layers = tiny_layers(3);
        let mut auto = Engine::native_auto(
            layers.clone(),
            &EnergyModel::table_i(),
            &TimeModel::default_model(),
            Objective::Energy,
        );
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..2 * 12).map(|_| rng.f32()).collect();
        let want = oracle_forward(&layers, &x, 2);
        let got = auto.forward(&x, 2).unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
        assert_eq!(auto.formats().len(), 3);
    }

    #[test]
    fn threaded_forward_bit_identical_to_serial() {
        let layers = tiny_layers(11);
        let mut rng = Rng::new(5);
        let batch = 6;
        let x: Vec<f32> = (0..batch * 12).map(|_| rng.f32() - 0.5).collect();
        for kind in FormatKind::ALL {
            let mut serial = Engine::native_fixed(layers.clone(), kind);
            let want = serial.forward(&x, batch).unwrap();
            let mut par = Engine::native_fixed(layers.clone(), kind).with_threads(4);
            assert_eq!(par.threads(), 4);
            assert_eq!(par.shard_plans().len(), 3);
            assert_eq!(par.forward(&x, batch).unwrap(), want, "{kind:?} @4");
            // Back to serial: plans drop, results unchanged.
            par.set_threads(1);
            assert_eq!(par.threads(), 1);
            assert!(par.shard_plans().is_empty());
            assert_eq!(par.forward(&x, batch).unwrap(), want, "{kind:?} @1");
        }
    }

    #[test]
    fn to_codes_roundtrip() {
        let m = crate::paper_example_matrix();
        let (codes, omega) = to_codes(&m);
        assert_eq!(omega, vec![0.0, 2.0, 3.0, 4.0]);
        for (i, &v) in m.data().iter().enumerate() {
            assert_eq!(omega[codes[i] as usize], v);
        }
    }

    #[test]
    fn classify_argmax() {
        let layers = vec![(
            "out".into(),
            Dense::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0], vec![0.5, 0.5]]),
            vec![0.0; 3],
        )];
        let mut e = Engine::native_fixed(layers, FormatKind::Dense);
        let pred = e.classify(&[3.0, 0.0, 0.0, 3.0], 2).unwrap();
        assert_eq!(pred, vec![0, 1]);
    }

    #[test]
    fn storage_reflects_selected_formats() {
        let layers = tiny_layers(5);
        let dense = Engine::native_fixed(layers.clone(), FormatKind::Dense);
        let cser = Engine::native_fixed(layers, FormatKind::Cser);
        assert!(cser.storage_bits() < dense.storage_bits());
    }

    #[test]
    fn pack_cold_start_reproduces_engine_bit_exactly() {
        let layers = tiny_layers(8);
        let mut original = Engine::native_auto(
            layers,
            &EnergyModel::table_i(),
            &TimeModel::default_model(),
            Objective::Energy,
        );
        let path = std::env::temp_dir().join(format!(
            "cer-engine-pack-test-{}.cerpack",
            std::process::id()
        ));
        let (file_bytes, manifest) = original
            .save_pack(&path, "tiny-net", "argmin energy (modeled)")
            .unwrap();
        assert!(file_bytes > 0);
        assert_eq!(manifest.layers.len(), 3);
        assert!(manifest.layers.iter().all(|l| l.payload_bytes > 0));

        let mut cold = Engine::from_pack(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(cold.backend(), Backend::Native);
        assert_eq!(cold.formats(), original.formats());
        assert_eq!(cold.storage_bits(), original.storage_bits());

        // Same kernels over bit-identical layers: outputs are bit-exact.
        let mut rng = Rng::new(31);
        let batch = 3;
        let x: Vec<f32> = (0..batch * 12).map(|_| rng.f32() - 0.5).collect();
        let a = original.forward(&x, batch).unwrap();
        let b = cold.forward(&x, batch).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn from_pack_missing_file_errors() {
        let e = Engine::from_pack(Path::new("/nonexistent/nope.cerpack")).unwrap_err();
        assert!(format!("{e:#}").contains("nope.cerpack"));
    }
}
