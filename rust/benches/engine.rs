//! Coordinator benchmarks: engine forward latency per backend/batch size,
//! and server throughput through the dynamic batcher. Needs
//! `make artifacts` (skips gracefully otherwise).
//!
//! Run: `cargo bench --bench engine`

use std::path::Path;

use cer::coordinator::batcher::BatcherConfig;
use cer::coordinator::{Backend, Engine, InferenceServer, Objective, ServerConfig};
use cer::formats::FormatKind;
use cer::runtime::MlpArtifacts;
use cer::util::bench::{bench, fmt_ns, time_median_ns};

fn main() {
    let Ok(art) = MlpArtifacts::load(Path::new("artifacts")) else {
        eprintln!("artifacts/ not found — run `make artifacts` first; skipping engine bench");
        return;
    };

    // Native engine, each fixed format + auto selection.
    for kind in FormatKind::ALL {
        let layers: Vec<(String, cer::formats::Dense, Vec<f32>)> = art
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| (format!("fc{i}"), l.quantized.clone(), l.bias.clone()))
            .collect();
        let mut engine = Engine::native_fixed(layers, kind);
        for batch in [1usize, 32] {
            let x = vec![0.1f32; batch * art.in_dim()];
            let r = bench(
                &format!("engine/native-{}/batch{batch}", kind.name()),
                3,
                11,
                || {
                    let y = engine.forward(&x, batch).unwrap();
                    std::hint::black_box(&y);
                },
            );
            let _ = r;
        }
    }

    // XLA backends at their static batch.
    for backend in [Backend::XlaDense, Backend::XlaCser] {
        let mut engine = Engine::from_artifacts(&art, backend, Objective::Energy).unwrap();
        let batch = engine.required_batch().unwrap();
        let x = vec![0.1f32; batch * art.in_dim()];
        let per = time_median_ns(2, 9, || {
            let y = engine.forward(&x, batch).unwrap();
            std::hint::black_box(&y);
        });
        println!(
            "engine/{backend:?}/batch{batch}: {} per forward ({} per sample)",
            fmt_ns(per),
            fmt_ns(per / batch as f64)
        );
    }

    // Server throughput (closed-loop flood).
    for max_batch in [1usize, 8, 32, 128] {
        let art_clone = art.clone();
        let srv = InferenceServer::spawn(
            move || Engine::from_artifacts(&art_clone, Backend::Native, Objective::Energy),
            ServerConfig {
                batcher: BatcherConfig {
                    max_batch,
                    max_delay_us: 500,
                },
                ..ServerConfig::default()
            },
        );
        let n = 4000usize;
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                let s = i % art.n_test;
                srv.submit(art.test_x[s * art.in_dim()..(s + 1) * art.in_dim()].to_vec())
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "server/max_batch={max_batch:<4} {:>9.0} req/s  ({})",
            n as f64 / dt,
            srv.metrics().summary()
        );
        srv.shutdown();
    }
}
