//! Shannon entropy of empirical element distributions (§II), and the
//! feasibility boundaries of the entropy–sparsity plane (§IV-D, Fig. 3).

use crate::formats::Dense;
use crate::formats::codebook::frequency_codebook;

/// Shannon entropy (bits) of a pmf. Zero-probability outcomes contribute 0.
pub fn entropy_bits(pmf: &[f64]) -> f64 {
    let sum: f64 = pmf.iter().sum();
    debug_assert!((sum - 1.0).abs() < 1e-6, "pmf sums to {sum}");
    pmf.iter()
        .filter(|&&p| p > 0.0)
        .map(|&p| -p * p.log2())
        .sum()
}

/// Entropy of the empirical element distribution of a matrix.
pub fn matrix_entropy(m: &Dense) -> f64 {
    let n = (m.rows() * m.cols()) as f64;
    frequency_codebook(m)
        .iter()
        .map(|&(_, c)| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum()
}

/// Minimum achievable entropy given that the most frequent element has mass
/// `p0` — the bottom boundary of the feasible (H, p₀) region (the paper's
/// Fig. 3 caption: "distributions whose entropy equal their respective
/// min-entropy, that is, where H = −log₂ p₀").
///
/// Every other value is bounded by `p0`, so the most concentrated
/// distribution packs ⌊1/p₀⌋ values at mass `p0` plus one remainder:
/// `H_min = −⌊1/p₀⌋·p₀·lg p₀ − r·lg r`. For `p0 ≥ 0.5` this reduces to the
/// binary entropy of (p₀, 1−p₀); for small `p0` it approaches −lg p₀.
pub fn min_entropy(p0: f64) -> f64 {
    if p0 <= 0.0 || p0 >= 1.0 {
        return 0.0;
    }
    let full = (1.0 / p0).floor();
    let r = (1.0 - full * p0).max(0.0);
    let mut h = -full * p0 * p0.log2();
    if r > 1e-12 {
        h -= r * r.log2();
    }
    h
}

/// Maximum achievable entropy given mass `p0` on the most frequent element
/// and `k` distinct values total (remaining mass uniform over k−1 values:
/// the spike-and-slab family) — the right boundary of Fig. 3.
///
/// Note: if `p0 < 1/k`, the "most frequent element" constraint caps the
/// uniform tail at mass `p0` each; the unconstrained formula would violate
/// p₀-is-max. We return the constrained maximum.
pub fn max_entropy(p0: f64, k: usize) -> f64 {
    assert!(k >= 1);
    if k == 1 || p0 >= 1.0 {
        return 0.0;
    }
    let tail = 1.0 - p0;
    let per = tail / (k - 1) as f64;
    if per <= p0 {
        // spike-and-slab: H = -p0·lg p0 − tail·lg(per)
        -(p0 * p0.log2()) - tail * per.log2()
    } else {
        // p0 too small to dominate a uniform tail; max is uniform over k.
        (k as f64).log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::paper_example_matrix;

    #[test]
    fn uniform_pmf_entropy() {
        let pmf = vec![0.25; 4];
        assert!((entropy_bits(&pmf) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn deterministic_pmf_entropy_zero() {
        assert_eq!(entropy_bits(&[1.0, 0.0, 0.0]), 0.0);
    }

    #[test]
    fn paper_example_entropy() {
        let h = matrix_entropy(&paper_example_matrix());
        // {32,21,4,3}/60 → ≈ 1.53 bits.
        assert!(h > 1.4 && h < 1.7, "H = {h}");
    }

    #[test]
    fn min_entropy_is_binary_entropy_for_large_p0() {
        assert!((min_entropy(0.5) - 1.0).abs() < 1e-12);
        let p0: f64 = 0.9;
        let binary = -(p0 * p0.log2() + 0.1f64 * 0.1f64.log2());
        assert!((min_entropy(0.9) - binary).abs() < 1e-12);
        assert_eq!(min_entropy(1.0), 0.0);
        assert_eq!(min_entropy(0.0), 0.0);
    }

    #[test]
    fn min_entropy_approaches_neg_log_p0_for_small_p0() {
        // Fig. 3's bottom boundary: H_min = −lg p₀ when 1/p₀ is integral.
        assert!((min_entropy(1.0 / 16.0) - 4.0).abs() < 1e-9);
        assert!((min_entropy(1.0 / 64.0) - 6.0).abs() < 1e-9);
        // And always ≥ the unconstrained binary entropy.
        for p0 in [0.05, 0.1, 0.3] {
            let q = 1.0 - p0;
            let binary = -(p0 * (p0 as f64).log2() + q * q.log2());
            assert!(min_entropy(p0) >= binary - 1e-12);
        }
    }

    #[test]
    fn max_entropy_spike_and_slab() {
        // p0 = 0.5, K = 3: H = 0.5 + 0.5·lg(4) = 0.5·1 + 0.5·2 = 1.5.
        let h = max_entropy(0.5, 3);
        assert!((h - 1.5).abs() < 1e-12, "{h}");
        // Min ≤ max always.
        for p0 in [0.1, 0.3, 0.6, 0.9] {
            assert!(min_entropy(p0) <= max_entropy(p0, 128) + 1e-12);
        }
    }

    #[test]
    fn max_entropy_small_p0_caps_at_uniform() {
        // p0 = 1/128 exactly uniform: H = 7.
        let h = max_entropy(1.0 / 128.0, 128);
        assert!((h - 7.0).abs() < 1e-9);
    }

    #[test]
    fn renyi_relation_p0_geq_2_pow_neg_h() {
        // §IV: p0 ≥ 2^{-H} for any distribution where p0 is the max.
        let m = paper_example_matrix();
        let h = matrix_entropy(&m);
        let p0 = 32.0 / 60.0;
        assert!(p0 >= 2f64.powf(-h));
    }
}
