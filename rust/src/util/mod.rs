//! Small self-contained utilities: deterministic RNG, discrete sampling,
//! CSV emission, terminal tables and the CRC-32 used by `.cerpack`
//! checksums.
//!
//! Everything here is dependency-free so the core library stays portable;
//! determinism (seeded RNG, stable float formatting) is load-bearing for the
//! reproduction harness — every table in EXPERIMENTS.md is regenerable
//! bit-for-bit from a seed.

pub mod alias;
pub mod bench;
pub mod benchgate;
pub mod crc32;
pub mod csv;
pub mod json;
pub mod rng;
pub mod table;

pub use alias::AliasTable;
pub use crc32::crc32;
pub use rng::Rng;

/// Human-readable byte size (`12.3 KB`, `1.1 MB`, ...).
pub fn human_bytes(bytes: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = bytes;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0} {}", UNITS[u])
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// `x{ratio}` formatting used throughout the paper's tables (e.g. `x2.74`).
pub fn ratio(baseline: f64, value: f64) -> String {
    if value == 0.0 {
        return "x∞".to_string();
    }
    format!("x{:.2}", baseline / value)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_scales() {
        assert_eq!(human_bytes(512.0), "512 B");
        assert_eq!(human_bytes(2048.0), "2.00 KB");
        assert_eq!(human_bytes(3.5 * 1024.0 * 1024.0), "3.50 MB");
    }

    #[test]
    fn ratio_matches_paper_style() {
        assert_eq!(ratio(114.72, 41.13), "x2.79");
        assert_eq!(ratio(10.0, 0.0), "x∞");
    }
}
