//! Closed-form storage and energy expressions of §IV — equations (1), (3),
//! (9), (11) for storage and (2), (4), (10), (12) for the dot-product
//! energy — plus the Corollary 2.1 entropy bound.
//!
//! These are the *exact* (non-asymptotic) forms: the O(1/n), O(1/N) terms
//! the paper absorbs are kept explicit, so on any concrete matrix the
//! analytic values must equal the measured storage / traced energy exactly
//! (modulo the all-rows-nonempty assumption for the per-row `−1` add
//! terms). The unit tests and the property tests in `tests/` enforce this.

use crate::formats::{Cer, Dense, IndexWidth, VALUE_BITS};
use crate::formats::codebook::frequency_codebook;

use super::energy::{EnergyModel, MemTier};
use super::opcount::BaseOp;

/// Distribution statistics of a matrix — the quantities Theorems 1 & 2 are
/// phrased in (§IV notation).
#[derive(Clone, Copy, Debug)]
pub struct DistStats {
    /// Row dimension m.
    pub m: usize,
    /// Column dimension n.
    pub n: usize,
    /// Number of distinct values K.
    pub k: usize,
    /// Probability mass of the most frequent value (the paper's p₀; equals
    /// the sparsity level when the matrix is decomposed so that ω₀ = 0).
    pub p0: f64,
    /// Shannon entropy H of the empirical element distribution (bits).
    pub entropy: f64,
    /// Average distinct shared values per row excluding ω₀ (k̄).
    pub kbar: f64,
    /// Average padded (empty) CER runs per row (k̃).
    pub ktilde: f64,
}

impl DistStats {
    /// Measure all statistics of a dense matrix.
    pub fn measure(mat: &Dense) -> DistStats {
        let (m, n) = (mat.rows(), mat.cols());
        let nf = (m * n) as f64;
        let codebook = frequency_codebook(mat);
        let k = codebook.len();
        let p0 = codebook[0].1 as f64 / nf;
        let entropy = codebook
            .iter()
            .map(|&(_, c)| {
                let p = c as f64 / nf;
                -p * p.log2()
            })
            .sum::<f64>();
        // k̄ and k̃ come from the CER encoding (cheapest exact way).
        let cer = Cer::from_dense(mat);
        DistStats {
            m,
            n,
            k,
            p0,
            entropy,
            kbar: cer.kbar(),
            ktilde: cer.ktilde(),
        }
    }

    /// Total element count N.
    pub fn total(&self) -> usize {
        self.m * self.n
    }
}

// ---------------------------------------------------------------------------
// Storage equations (bits per matrix element).
// ---------------------------------------------------------------------------

/// Eq. (1): dense storage per element.
pub fn storage_dense() -> f64 {
    VALUE_BITS as f64
}

/// Eq. (3), exact form: CSR storage per element.
///
/// `(1-p0)(b_Ω + b_colI) + (m+1)·b_rowPtr / N`.
pub fn storage_csr(s: &DistStats) -> f64 {
    let n_total = s.total() as f64;
    let nnz = (1.0 - s.p0) * n_total;
    let b_coli = IndexWidth::minimal(s.n.saturating_sub(1)).bits() as f64;
    let b_rptr = IndexWidth::minimal(nnz.round() as usize).bits() as f64;
    ((VALUE_BITS as f64 + b_coli) * nnz + (s.m as f64 + 1.0) * b_rptr) / n_total
}

/// Eq. (9), exact form: CER storage per element.
///
/// `K·b_Ω/N + (1-p0)·b_colI + (m(k̄+k̃)+1)·b_ΩPtr/N + (m+1)·b_rowPtr/N`.
pub fn storage_cer(s: &DistStats) -> f64 {
    let n_total = s.total() as f64;
    let nnz = (1.0 - s.p0) * n_total;
    let runs = s.m as f64 * (s.kbar + s.ktilde);
    let b_coli = IndexWidth::minimal(s.n.saturating_sub(1)).bits() as f64;
    let b_optr = IndexWidth::minimal(nnz.round() as usize).bits() as f64;
    let b_rptr = IndexWidth::minimal(runs.round() as usize).bits() as f64;
    (s.k as f64 * VALUE_BITS as f64
        + nnz * b_coli
        + (runs + 1.0) * b_optr
        + (s.m as f64 + 1.0) * b_rptr)
        / n_total
}

/// Eq. (11), exact form: CSER storage per element.
pub fn storage_cser(s: &DistStats) -> f64 {
    let n_total = s.total() as f64;
    let nnz = (1.0 - s.p0) * n_total;
    let runs = s.m as f64 * s.kbar;
    let b_coli = IndexWidth::minimal(s.n.saturating_sub(1)).bits() as f64;
    let b_optr = IndexWidth::minimal(nnz.round() as usize).bits() as f64;
    let b_rptr = IndexWidth::minimal(runs.round() as usize).bits() as f64;
    let b_oidx = IndexWidth::minimal(s.k.saturating_sub(1)).bits() as f64;
    (s.k as f64 * VALUE_BITS as f64
        + nnz * b_coli
        + runs * b_oidx
        + (runs + 1.0) * b_optr
        + (s.m as f64 + 1.0) * b_rptr)
        / n_total
}

// ---------------------------------------------------------------------------
// Energy equations (pJ per matrix element of one matvec).
// ---------------------------------------------------------------------------

/// The concrete array tiers of a represented matrix — needed because the
/// cost functions γ, δ depend on where each array lives.
#[derive(Clone, Copy, Debug)]
struct Tiers {
    input: MemTier,
    output: MemTier,
    weights: MemTier,
    coli: MemTier,
    optr: MemTier,
    rptr: MemTier,
    oidx: MemTier,
}

fn tiers_for(s: &DistStats) -> Tiers {
    let n_total = s.total() as f64;
    let nnz = (1.0 - s.p0) * n_total;
    let b_coli = IndexWidth::minimal(s.n.saturating_sub(1)).bytes() as f64;
    Tiers {
        input: MemTier::for_bytes(s.n as u64 * 4),
        output: MemTier::for_bytes(s.m as u64 * 4),
        // Weight array size differs per format; computed where needed. For
        // CER/CSER the codebook is tiny; for dense it is N·4, for CSR nnz·4.
        weights: MemTier::for_bytes((s.k as u64) * 4),
        coli: MemTier::for_bytes((nnz * b_coli) as u64),
        optr: MemTier::for_bytes(
            ((s.m as f64 * (s.kbar + s.ktilde) + 1.0)
                * IndexWidth::minimal(nnz.round() as usize).bytes() as f64) as u64,
        ),
        rptr: MemTier::for_bytes(
            ((s.m + 1) as f64
                * IndexWidth::minimal((s.m as f64 * (s.kbar + s.ktilde)).round() as usize)
                    .bytes() as f64) as u64,
        ),
        oidx: MemTier::for_bytes(
            (s.m as f64 * s.kbar * IndexWidth::minimal(s.k.saturating_sub(1)).bytes() as f64)
                as u64,
        ),
    }
}

/// Eq. (2), exact: dense matvec energy per element.
pub fn energy_dense(s: &DistStats, e: &EnergyModel) -> f64 {
    let t = tiers_for(s);
    let w_tier = MemTier::for_bytes(s.total() as u64 * 4);
    let per_el = e.cost_pj(BaseOp::Read, 32, t.input)
        + e.cost_pj(BaseOp::Read, VALUE_BITS, w_tier)
        + e.cost_pj(BaseOp::Mul, 32, w_tier)
        + e.cost_pj(BaseOp::Sum, 32, w_tier);
    // −1 add per row + 1 write per row.
    let per_row = e.cost_pj(BaseOp::Write, 32, t.output) - e.cost_pj(BaseOp::Sum, 32, w_tier);
    per_el + per_row / s.n as f64
}

/// Eq. (4), exact: CSR matvec energy per element (all rows assumed
/// non-empty, as in the theorem proofs).
pub fn energy_csr(s: &DistStats, e: &EnergyModel) -> f64 {
    let t = tiers_for(s);
    let n_total = s.total() as f64;
    let nnz = (1.0 - s.p0) * n_total;
    let vals_tier = MemTier::for_bytes((nnz * 4.0) as u64);
    let b_coli = IndexWidth::minimal(s.n.saturating_sub(1)).bits();
    let b_rptr = IndexWidth::minimal(nnz.round() as usize).bits();
    let rptr_tier = MemTier::for_bytes(((s.m + 1) * b_rptr as usize / 8) as u64);
    let per_nnz = e.cost_pj(BaseOp::Read, VALUE_BITS, vals_tier)
        + e.cost_pj(BaseOp::Read, b_coli, t.coli)
        + e.cost_pj(BaseOp::Read, 32, t.input)
        + e.cost_pj(BaseOp::Mul, 32, vals_tier)
        + e.cost_pj(BaseOp::Sum, 32, vals_tier);
    let per_row = 2.0 * e.cost_pj(BaseOp::Read, b_rptr, rptr_tier)
        + e.cost_pj(BaseOp::Write, 32, t.output)
        - e.cost_pj(BaseOp::Sum, 32, vals_tier);
    (per_nnz * nnz + per_row * s.m as f64) / n_total
}

/// Eq. (10), exact: CER matvec energy per element.
pub fn energy_cer(s: &DistStats, e: &EnergyModel) -> f64 {
    let t = tiers_for(s);
    let n_total = s.total() as f64;
    let nnz = (1.0 - s.p0) * n_total;
    let b_coli = IndexWidth::minimal(s.n.saturating_sub(1)).bits();
    let b_optr = IndexWidth::minimal(nnz.round() as usize).bits();
    let runs = s.m as f64 * (s.kbar + s.ktilde);
    let b_rptr = IndexWidth::minimal(runs.round() as usize).bits();
    // Per listed element: colI load + input load + add.
    let per_nnz = e.cost_pj(BaseOp::Read, b_coli, t.coli)
        + e.cost_pj(BaseOp::Read, 32, t.input)
        + e.cost_pj(BaseOp::Sum, 32, t.input);
    // Per non-empty run (m·k̄ of them): Ω load + mul + one ΩPtr load.
    let per_run = e.cost_pj(BaseOp::Read, VALUE_BITS, t.weights)
        + e.cost_pj(BaseOp::Mul, 32, t.weights)
        + e.cost_pj(BaseOp::Read, b_optr, t.optr);
    // Per padded run: one ΩPtr load.
    let per_pad = e.cost_pj(BaseOp::Read, b_optr, t.optr);
    // Per row: 2 rowPtr loads + trailing ΩPtr load + write − 1 add.
    let per_row = 2.0 * e.cost_pj(BaseOp::Read, b_rptr, t.rptr)
        + e.cost_pj(BaseOp::Read, b_optr, t.optr)
        + e.cost_pj(BaseOp::Write, 32, t.output)
        - e.cost_pj(BaseOp::Sum, 32, t.input);
    (per_nnz * nnz
        + per_run * s.m as f64 * s.kbar
        + per_pad * s.m as f64 * s.ktilde
        + per_row * s.m as f64)
        / n_total
}

/// Eq. (12), exact: CSER matvec energy per element.
pub fn energy_cser(s: &DistStats, e: &EnergyModel) -> f64 {
    let t = tiers_for(s);
    let n_total = s.total() as f64;
    let nnz = (1.0 - s.p0) * n_total;
    let b_coli = IndexWidth::minimal(s.n.saturating_sub(1)).bits();
    let b_optr = IndexWidth::minimal(nnz.round() as usize).bits();
    let runs = s.m as f64 * s.kbar;
    let b_rptr = IndexWidth::minimal(runs.round() as usize).bits();
    let b_oidx = IndexWidth::minimal(s.k.saturating_sub(1)).bits();
    // CSER's ΩPtr/rowPtr arrays are shorter than CER's (no padded runs) —
    // recompute their tiers instead of reusing `tiers_for`.
    let optr_tier = MemTier::for_bytes(((runs + 1.0) * b_optr as f64 / 8.0) as u64);
    let rptr_tier = MemTier::for_bytes(((s.m + 1) as f64 * b_rptr as f64 / 8.0) as u64);
    let per_nnz = e.cost_pj(BaseOp::Read, b_coli, t.coli)
        + e.cost_pj(BaseOp::Read, 32, t.input)
        + e.cost_pj(BaseOp::Sum, 32, t.input);
    // Per run: Ω load + mul + ΩPtr load + ΩI load.
    let per_run = e.cost_pj(BaseOp::Read, VALUE_BITS, t.weights)
        + e.cost_pj(BaseOp::Mul, 32, t.weights)
        + e.cost_pj(BaseOp::Read, b_optr, optr_tier)
        + e.cost_pj(BaseOp::Read, b_oidx, t.oidx);
    let per_row = 2.0 * e.cost_pj(BaseOp::Read, b_rptr, rptr_tier)
        + e.cost_pj(BaseOp::Read, b_optr, optr_tier)
        + e.cost_pj(BaseOp::Write, 32, t.output)
        - e.cost_pj(BaseOp::Sum, 32, t.input);
    (per_nnz * nnz + per_run * runs + per_row * s.m as f64) / n_total
}

/// Corollary 2.1: upper bound on per-element storage/energy scale factor,
/// `O(1 − 2^{-H}) + O(K/n) + O(1/N)` with unit constants folded to the
/// dominating per-element terms. Used by the monotonicity property tests —
/// as H decreases (fixed K, n), the bound and both S/E must shrink.
pub fn corollary_bound(s: &DistStats) -> f64 {
    (1.0 - 2f64.powf(-s.entropy)) + s.k as f64 / s.n as f64 + 1.0 / s.total() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{Cser, Csr, MatrixFormat};
    use crate::kernels::AnyMatrix;
    use crate::paper_example_matrix;

    fn paper_stats() -> DistStats {
        DistStats::measure(&paper_example_matrix())
    }

    #[test]
    fn measured_stats_match_paper_example() {
        let s = paper_stats();
        assert_eq!(s.m, 5);
        assert_eq!(s.n, 12);
        assert_eq!(s.k, 4);
        assert!((s.p0 - 32.0 / 60.0).abs() < 1e-12);
        assert!((s.kbar - 2.0).abs() < 1e-12);
        assert_eq!(s.ktilde, 0.0);
        // H of {32,21,4,3}/60.
        let h: f64 = [32.0, 21.0, 4.0, 3.0]
            .iter()
            .map(|c| {
                let p: f64 = c / 60.0;
                -p * p.log2()
            })
            .sum();
        assert!((s.entropy - h).abs() < 1e-12);
    }

    #[test]
    fn analytic_storage_matches_measured_exactly() {
        let m = paper_example_matrix();
        let s = paper_stats();
        let n_total = 60.0;
        let measured = |a: &dyn MatrixFormat| a.storage().total_bits() as f64 / n_total;
        assert!((storage_dense() - 32.0).abs() < 1e-12);
        let csr = Csr::from_dense(&m);
        assert!((storage_csr(&s) - measured(&csr)).abs() < 1e-9);
        let cer = Cer::from_dense(&m);
        assert!((storage_cer(&s) - measured(&cer)).abs() < 1e-9);
        let cser = Cser::from_dense(&m);
        assert!((storage_cser(&s) - measured(&cser)).abs() < 1e-9);
    }

    #[test]
    fn analytic_energy_matches_trace_exactly() {
        // The paper example has every row non-empty, so the exact analytic
        // forms must equal the traced energies to float precision.
        let m = paper_example_matrix();
        let s = paper_stats();
        let e = EnergyModel::table_i();
        let n_total = 60.0;
        let traced = |k| {
            super::super::trace::trace_matvec(&AnyMatrix::encode(k, &m)).energy_pj(&e) / n_total
        };
        use crate::formats::FormatKind::*;
        assert!((energy_dense(&s, &e) - traced(Dense)).abs() < 1e-9);
        assert!((energy_csr(&s, &e) - traced(Csr)).abs() < 1e-9);
        assert!((energy_cer(&s, &e) - traced(Cer)).abs() < 1e-9);
        assert!((energy_cser(&s, &e) - traced(Cser)).abs() < 1e-9);
    }

    #[test]
    fn corollary_bound_positive_and_below_two() {
        let s = paper_stats();
        let b = corollary_bound(&s);
        assert!(b > 0.0 && b < 2.0);
    }
}
