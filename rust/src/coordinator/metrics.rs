//! Serving metrics: lock-free counters and a fixed-bucket latency
//! histogram shared between worker threads and callers.
//!
//! Everything here is increment-only atomics — no locks on the request
//! path. The [`LatencyHistogram`] uses log-linear buckets (4 sub-buckets
//! per power of two), so a single relaxed `fetch_add` records a sample
//! and quantile reads are a 252-slot scan with bounded (≤ 25%) relative
//! error — the structure the `/metrics` endpoint of the network front
//! end ([`crate::serve`]) exposes as p50/p99/p999.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Number of histogram buckets: values 0–3 get exact buckets, then 4
/// sub-buckets per octave up to the full `u64` range.
pub const LATENCY_BUCKETS: usize = 252;

/// Lock-free fixed-bucket latency histogram (microseconds).
///
/// Buckets are log-linear: exact for 0–3 µs, then each power-of-two
/// octave `[2^k, 2^{k+1})` is split into 4 equal sub-buckets. Recording
/// is one relaxed atomic increment; [`LatencyHistogram::quantile`]
/// returns the *upper edge* of the bucket holding the requested rank, so
/// reported quantiles are conservative (never under-report) with at most
/// ~25% relative overshoot.
///
/// ```
/// use cer::coordinator::metrics::LatencyHistogram;
/// let h = LatencyHistogram::default();
/// for us in [100, 200, 300, 400, 10_000] {
///     h.record_us(us);
/// }
/// assert_eq!(h.count(), 5);
/// assert!(h.quantile(0.5) >= 200 && h.quantile(0.5) < 400);
/// assert!(h.quantile(0.999) >= 10_000);
/// ```
#[derive(Debug)]
pub struct LatencyHistogram {
    counts: [AtomicU64; LATENCY_BUCKETS],
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// Bucket index for a microsecond reading.
#[inline]
fn bucket_index(us: u64) -> usize {
    if us < 4 {
        return us as usize;
    }
    let octave = 63 - us.leading_zeros() as u64; // >= 2 here
    let sub = (us >> (octave - 2)) & 3;
    ((octave * 4 + sub) as usize - 4).min(LATENCY_BUCKETS - 1)
}

/// Inclusive upper edge (µs) of bucket `i` — what quantile reads report.
fn bucket_upper_us(i: usize) -> u64 {
    if i < 4 {
        return i as u64;
    }
    let octave = (i as u64 + 4) / 4;
    let sub = (i as u64 + 4) % 4;
    // Bucket covers [(4+sub) << (octave-2), (5+sub) << (octave-2)); the
    // top octave's edge exceeds u64 — widen, then clamp.
    let upper = ((5 + sub) as u128) << (octave - 2);
    (upper - 1).min(u64::MAX as u128) as u64
}

impl LatencyHistogram {
    /// Record one latency sample (lock-free, relaxed).
    #[inline]
    pub fn record_us(&self, us: u64) {
        self.counts[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// The `q`-quantile (0 < q <= 1) in µs: upper edge of the bucket
    /// holding rank `ceil(q·count)`. Returns 0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        let snapshot: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let total: u64 = snapshot.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, c) in snapshot.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_upper_us(i);
            }
        }
        bucket_upper_us(LATENCY_BUCKETS - 1)
    }

    /// Median latency (µs).
    pub fn p50(&self) -> u64 {
        self.quantile(0.5)
    }

    /// 99th percentile latency (µs).
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// 99.9th percentile latency (µs).
    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }

    /// Add every sample of `other` into `self` (used to merge per-worker
    /// or per-thread histograms into one report).
    pub fn absorb(&self, other: &LatencyHistogram) {
        for (dst, src) in self.counts.iter().zip(&other.counts) {
            let v = src.load(Ordering::Relaxed);
            if v > 0 {
                dst.fetch_add(v, Ordering::Relaxed);
            }
        }
    }

    /// Non-empty buckets as `(upper_edge_us, cumulative_count)` pairs —
    /// the shape a Prometheus-style `_bucket{le=...}` rendering wants.
    pub fn cumulative_buckets(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            let v = c.load(Ordering::Relaxed);
            if v > 0 {
                cum += v;
                out.push((bucket_upper_us(i), cum));
            }
        }
        out
    }
}

/// Cumulative serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted.
    pub requests: AtomicU64,
    /// Requests completed.
    pub completed: AtomicU64,
    /// Batches executed.
    pub batches: AtomicU64,
    /// Σ batch sizes (for mean batch size).
    pub batched_requests: AtomicU64,
    /// Σ request latency (µs, enqueue → response).
    pub total_latency_us: AtomicU64,
    /// Max observed latency (µs).
    pub max_latency_us: AtomicU64,
    /// Per-request latency distribution (enqueue → response, µs).
    pub latency: LatencyHistogram,
    /// Batcher queue depth at the last sample (requests waiting).
    pub queue_depth: AtomicU64,
    /// Deepest queue ever sampled.
    pub queue_depth_peak: AtomicU64,
    /// Age (µs) of the oldest queued request at the last sample — how
    /// long work sits before a batch picks it up.
    pub queue_age_us: AtomicU64,
    /// Cumulative stolen chunks across the worker engine's lanes (a fast
    /// lane draining a straggler's pooled chunk). Snapshot of the
    /// engine's own counter — see [`Metrics::record_exec`].
    pub steals_total: AtomicU64,
    /// Waves whose shard plans were rebuilt by timing-driven re-sharding.
    pub waves_replanned: AtomicU64,
    /// Lane-time imbalance of the most recent forward,
    /// `max_lane_ns / mean_lane_ns`, in milli-units (1000 = perfectly
    /// balanced). A gauge.
    pub lane_imbalance_milli: AtomicU64,
}

impl Metrics {
    pub fn shared() -> Arc<Metrics> {
        Arc::new(Metrics::default())
    }

    pub fn record_batch(&self, batch_size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(batch_size as u64, Ordering::Relaxed);
    }

    pub fn record_latency(&self, us: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.total_latency_us.fetch_add(us, Ordering::Relaxed);
        self.max_latency_us.fetch_max(us, Ordering::Relaxed);
        self.latency.record_us(us);
    }

    /// Sample the batcher queue: current depth (gauge), peak depth
    /// (high-water mark) and the oldest queued request's age in µs.
    /// Called by the serving workers after every push and drain, so the
    /// gauges track occupancy without any queue-side locking.
    pub fn record_queue(&self, depth: u64, age_us: u64) {
        self.queue_depth.store(depth, Ordering::Relaxed);
        self.queue_depth_peak.fetch_max(depth, Ordering::Relaxed);
        self.queue_age_us.store(age_us, Ordering::Relaxed);
    }

    /// Snapshot the execution plane's adaptive counters after a batch:
    /// cumulative steals and replanned waves (the engine owns the
    /// authoritative counts — `store` keeps them monotone without a
    /// read-modify-write) plus the last-wave lane-imbalance gauge.
    pub fn record_exec(&self, steals: u64, replans: u64, imbalance: f64) {
        self.steals_total.store(steals, Ordering::Relaxed);
        self.waves_replanned.store(replans, Ordering::Relaxed);
        self.lane_imbalance_milli
            .store((imbalance * 1000.0) as u64, Ordering::Relaxed);
    }

    /// Mean latency in µs over completed requests.
    pub fn mean_latency_us(&self) -> f64 {
        let n = self.completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.total_latency_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Mean batch size.
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
    }

    pub fn summary(&self) -> String {
        format!(
            "requests {} completed {} batches {} mean_batch {:.2} mean_latency {:.0}µs \
             p50 {}µs p99 {}µs max_latency {}µs",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch(),
            self.mean_latency_us(),
            self.latency.p50(),
            self.latency.p99(),
            self.max_latency_us.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let m = Metrics::default();
        m.record_batch(4);
        m.record_batch(2);
        for us in [100, 200, 300] {
            m.record_latency(us);
        }
        assert_eq!(m.mean_batch(), 3.0);
        assert_eq!(m.mean_latency_us(), 200.0);
        assert_eq!(m.max_latency_us.load(Ordering::Relaxed), 300);
        assert!(m.summary().contains("batches 2"));
        assert_eq!(m.latency.count(), 3);
    }

    #[test]
    fn queue_gauges_track_depth_peak_and_age() {
        let m = Metrics::default();
        m.record_queue(3, 150);
        m.record_queue(7, 900);
        m.record_queue(2, 40);
        // Depth and age are last-sample gauges; the peak is sticky.
        assert_eq!(m.queue_depth.load(Ordering::Relaxed), 2);
        assert_eq!(m.queue_age_us.load(Ordering::Relaxed), 40);
        assert_eq!(m.queue_depth_peak.load(Ordering::Relaxed), 7);
        // An empty sample zeroes the gauges but not the peak.
        m.record_queue(0, 0);
        assert_eq!(m.queue_depth.load(Ordering::Relaxed), 0);
        assert_eq!(m.queue_depth_peak.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn exec_snapshot_counters_and_gauge() {
        let m = Metrics::default();
        m.record_exec(12, 1, 1.5);
        assert_eq!(m.steals_total.load(Ordering::Relaxed), 12);
        assert_eq!(m.waves_replanned.load(Ordering::Relaxed), 1);
        assert_eq!(m.lane_imbalance_milli.load(Ordering::Relaxed), 1500);
        // Snapshot semantics: a later (larger) snapshot replaces.
        m.record_exec(40, 2, 1.0);
        assert_eq!(m.steals_total.load(Ordering::Relaxed), 40);
        assert_eq!(m.lane_imbalance_milli.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn empty_metrics_no_division_by_zero() {
        let m = Metrics::default();
        assert_eq!(m.mean_batch(), 0.0);
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.latency.quantile(0.5), 0);
        assert!(m.latency.cumulative_buckets().is_empty());
    }

    #[test]
    fn bucket_geometry_is_monotone_and_covers_u64() {
        // Every value lands in a bucket whose upper edge is >= the value
        // and < 1.25x the value (+1 for the integer edges), and indices
        // never decrease as values grow.
        let mut last_idx = 0usize;
        for shift in 0..63 {
            for off in [0u64, 1, 3] {
                let v = (1u64 << shift).saturating_add(off * (1u64 << shift) / 4);
                let idx = bucket_index(v);
                assert!(idx >= last_idx || v < 4, "non-monotone at {v}");
                last_idx = idx.max(last_idx);
                let upper = bucket_upper_us(idx);
                assert!(upper >= v.min(upper), "edge below value at {v}");
                if idx < LATENCY_BUCKETS - 1 {
                    assert!(
                        upper as f64 >= v as f64 && (upper as f64) < v as f64 * 1.25 + 1.0,
                        "edge {upper} too far from {v}"
                    );
                }
            }
        }
    }

    #[test]
    fn quantiles_bound_the_true_order_statistics() {
        let h = LatencyHistogram::default();
        for us in 1..=1000u64 {
            h.record_us(us);
        }
        assert_eq!(h.count(), 1000);
        // True p50 = 500, p99 = 990, p999 = 999; the histogram reports
        // the bucket upper edge: >= truth, < 1.25x truth.
        for (q, truth) in [(0.5, 500u64), (0.99, 990), (0.999, 999)] {
            let got = h.quantile(q);
            assert!(got >= truth, "q{q}: {got} < {truth}");
            assert!((got as f64) < truth as f64 * 1.25 + 1.0, "q{q}: {got} vs {truth}");
        }
        assert!(h.quantile(1.0) >= 1000);
    }

    #[test]
    fn histogram_absorb_merges_counts() {
        let a = LatencyHistogram::default();
        let b = LatencyHistogram::default();
        for us in [10, 20, 30] {
            a.record_us(us);
        }
        for us in [10_000, 20_000] {
            b.record_us(us);
        }
        a.absorb(&b);
        assert_eq!(a.count(), 5);
        assert!(a.quantile(1.0) >= 20_000);
        // b unchanged.
        assert_eq!(b.count(), 2);
    }

    #[test]
    fn concurrent_recording_is_lossless() {
        let h = Arc::new(LatencyHistogram::default());
        let mut joins = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..10_000u64 {
                    h.record_us(t * 1000 + i % 997);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
    }

    #[test]
    fn cumulative_buckets_are_cumulative() {
        let h = LatencyHistogram::default();
        for us in [5u64, 5, 100, 1000] {
            h.record_us(us);
        }
        let b = h.cumulative_buckets();
        assert_eq!(b.last().unwrap().1, 4);
        for w in b.windows(2) {
            assert!(w[0].0 < w[1].0 && w[0].1 < w[1].1);
        }
    }
}
