//! Vectorized dense/CSR kernels — the opt-in [`super::KernelBackend::Simd`]
//! paths.
//!
//! These kernels compute the same products as `dense_k`/`csr_k` but
//! accumulate each row in W-wide partial sums (W = 8 on AVX2, 4 on
//! SSE2/NEON) that are reduced horizontally at the end of the row. That
//! **reassociates the float additions**, so outputs are *numerically
//! close* to the scalar reference (relative error on the order of one ulp
//! per reassociated add) but not bit-identical. Consequently:
//!
//! * nothing in the crate calls these kernels unless the engine was
//!   explicitly given [`super::KernelBackend::Simd`];
//! * correctness is asserted by the tolerance-based differential suite
//!   (`tests/simd_differential.rs` and the in-module tests below), never
//!   by `assert_eq!` against the scalar path.
//!
//! The multi-rhs matmul tiles are widened from the scalar kernels' 4
//! columns to 8 and 16: the dense kernel streams each weight row once per
//! 16 (then 8) rhs columns with a vector dot per column-octet, and the CSR
//! kernel reuses each row's value/index stream across an 8-column tile.
//! Remainder columns fall through to the vectorized matvec per column.
//!
//! ISA selection: SSE2 is part of the x86_64 baseline and NEON part of
//! the aarch64 baseline, so those paths need no runtime check; AVX2 and
//! FMA are detected once per kernel call via `is_x86_feature_detected!`
//! (cached by std) and hoisted out of the row loops. When FMA is present
//! the dense matvec/tile kernels use `_mm256_fmadd_ps` variants — one
//! rounding per accumulate, still within the tolerance contract. On
//! targets with neither vector ISA every entry point here delegates to
//! the scalar kernels, so `KernelBackend::Simd` degrades to correct (and
//! bit-identical) scalar execution rather than failing.

use std::ops::Range;

#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
use super::finish;
use super::Epilogue;
use crate::exec::SyncCell;
use crate::formats::{Csr, Dense};
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
use crate::with_col_indices;

/// Element width of a typed column-index slice — lets the `u8`/`u16`/`u32`
/// arms of [`with_col_indices!`] share one monomorphic gather kernel via a
/// `(*const u8, idx_bytes)` pair instead of a generic parameter (generics
/// and `#[target_feature]` don't mix).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn elem_size<T>(_s: &[T]) -> usize {
    std::mem::size_of::<T>()
}

/// Byte-pointer view of a typed index slice (companion of [`elem_size`];
/// generic so the `u8` arm of the macro doesn't cast a pointer to its own
/// type).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
fn slice_ptr_bytes<T>(s: &[T]) -> *const u8 {
    s.as_ptr() as *const u8
}

/// Decode the `i`-th column index from a raw index array of `idx_bytes`-
/// wide elements.
///
/// # Safety
/// `base` must point to at least `(i + 1) * idx_bytes` readable bytes.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn load_idx(base: *const u8, idx_bytes: usize, i: usize) -> usize {
    match idx_bytes {
        1 => *base.add(i) as usize,
        2 => (base.add(i * 2) as *const u16).read_unaligned() as usize,
        _ => (base.add(i * 4) as *const u32).read_unaligned() as usize,
    }
}

/// `true` when the preferred (wider) ISA variant is available: AVX2 on
/// x86_64. On aarch64 NEON is the only variant, so the flag is inert.
#[cfg(target_arch = "x86_64")]
#[inline]
fn fast_isa() -> bool {
    is_x86_feature_detected!("avx2")
}

#[cfg(target_arch = "aarch64")]
#[inline]
fn fast_isa() -> bool {
    true
}

/// `true` when the fused multiply-add dense variants are usable: AVX2 +
/// FMA on x86_64 (both checked — FMA without AVX2 exists on no shipped
/// CPU, but the `target_feature` pairing requires both). On aarch64 the
/// flag is inert: the NEON paths are not fused, keeping one numeric
/// behavior per target.
#[cfg(target_arch = "x86_64")]
#[inline]
fn fma_isa() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

#[cfg(target_arch = "aarch64")]
#[inline]
fn fma_isa() -> bool {
    false
}

// ---------------------------------------------------------------------------
// Per-ISA dot primitives. Each returns one (or eight) f32 dot products with
// W-wide reassociated accumulation; drivers below are ISA-agnostic.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// # Safety
    /// Requires AVX2 (checked by the caller via `fast_isa`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_avx2(row: &[f32], x: &[f32]) -> f32 {
        let n = row.len().min(x.len());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_add_ps(
                acc0,
                _mm256_mul_ps(
                    _mm256_loadu_ps(row.as_ptr().add(i)),
                    _mm256_loadu_ps(x.as_ptr().add(i)),
                ),
            );
            acc1 = _mm256_add_ps(
                acc1,
                _mm256_mul_ps(
                    _mm256_loadu_ps(row.as_ptr().add(i + 8)),
                    _mm256_loadu_ps(x.as_ptr().add(i + 8)),
                ),
            );
            i += 16;
        }
        while i + 8 <= n {
            acc0 = _mm256_add_ps(
                acc0,
                _mm256_mul_ps(
                    _mm256_loadu_ps(row.as_ptr().add(i)),
                    _mm256_loadu_ps(x.as_ptr().add(i)),
                ),
            );
            i += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), _mm256_add_ps(acc0, acc1));
        let mut s: f32 = lanes.iter().sum();
        while i < n {
            s += row[i] * x[i];
            i += 1;
        }
        s
    }

    /// # Safety
    /// SSE2 is part of the x86_64 baseline; only the raw loads are unsafe
    /// and stay within `row`/`x` bounds.
    pub(super) unsafe fn dot_sse2(row: &[f32], x: &[f32]) -> f32 {
        let n = row.len().min(x.len());
        let mut acc0 = _mm_setzero_ps();
        let mut acc1 = _mm_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            acc0 = _mm_add_ps(
                acc0,
                _mm_mul_ps(_mm_loadu_ps(row.as_ptr().add(i)), _mm_loadu_ps(x.as_ptr().add(i))),
            );
            acc1 = _mm_add_ps(
                acc1,
                _mm_mul_ps(
                    _mm_loadu_ps(row.as_ptr().add(i + 4)),
                    _mm_loadu_ps(x.as_ptr().add(i + 4)),
                ),
            );
            i += 8;
        }
        while i + 4 <= n {
            acc0 = _mm_add_ps(
                acc0,
                _mm_mul_ps(_mm_loadu_ps(row.as_ptr().add(i)), _mm_loadu_ps(x.as_ptr().add(i))),
            );
            i += 4;
        }
        let mut lanes = [0.0f32; 4];
        _mm_storeu_ps(lanes.as_mut_ptr(), _mm_add_ps(acc0, acc1));
        let mut s: f32 = lanes.iter().sum();
        while i < n {
            s += row[i] * x[i];
            i += 1;
        }
        s
    }

    /// Fused multiply-add variant of [`dot_avx2`]: one rounding per
    /// accumulate instead of two, same W-wide reassociation. Still under
    /// the tolerance contract — fusing changes low-order bits relative
    /// to both the scalar path and the mul+add AVX2 path.
    ///
    /// # Safety
    /// Requires AVX2 **and** FMA (checked by the caller via `fma_isa`).
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn dot_avx2_fma(row: &[f32], x: &[f32]) -> f32 {
        let n = row.len().min(x.len());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(row.as_ptr().add(i)),
                _mm256_loadu_ps(x.as_ptr().add(i)),
                acc0,
            );
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(row.as_ptr().add(i + 8)),
                _mm256_loadu_ps(x.as_ptr().add(i + 8)),
                acc1,
            );
            i += 16;
        }
        while i + 8 <= n {
            acc0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(row.as_ptr().add(i)),
                _mm256_loadu_ps(x.as_ptr().add(i)),
                acc0,
            );
            i += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), _mm256_add_ps(acc0, acc1));
        let mut s: f32 = lanes.iter().sum();
        while i < n {
            s += row[i] * x[i];
            i += 1;
        }
        s
    }

    /// One weight row against eight rhs columns.
    ///
    /// # Safety
    /// Requires AVX2; every `xs[k]` must be at least `row.len()` long.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot8_avx2(row: &[f32], xs: &[&[f32]; 8]) -> [f32; 8] {
        let n = row.len();
        let mut acc = [_mm256_setzero_ps(); 8];
        let mut i = 0usize;
        while i + 8 <= n {
            let a = _mm256_loadu_ps(row.as_ptr().add(i));
            for (acc_k, xk) in acc.iter_mut().zip(xs.iter()) {
                *acc_k = _mm256_add_ps(*acc_k, _mm256_mul_ps(a, _mm256_loadu_ps(xk.as_ptr().add(i))));
            }
            i += 8;
        }
        let mut out = [0.0f32; 8];
        for ((o, acc_k), xk) in out.iter_mut().zip(acc.iter()).zip(xs.iter()) {
            let mut lanes = [0.0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), *acc_k);
            let mut s: f32 = lanes.iter().sum();
            let mut j = i;
            while j < n {
                s += row[j] * xk[j];
                j += 1;
            }
            *o = s;
        }
        out
    }

    /// Fused multiply-add variant of [`dot8_avx2`] (see
    /// [`dot_avx2_fma`] for the numeric contract).
    ///
    /// # Safety
    /// Requires AVX2 and FMA; every `xs[k]` must be at least `row.len()`
    /// long.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn dot8_avx2_fma(row: &[f32], xs: &[&[f32]; 8]) -> [f32; 8] {
        let n = row.len();
        let mut acc = [_mm256_setzero_ps(); 8];
        let mut i = 0usize;
        while i + 8 <= n {
            let a = _mm256_loadu_ps(row.as_ptr().add(i));
            for (acc_k, xk) in acc.iter_mut().zip(xs.iter()) {
                *acc_k = _mm256_fmadd_ps(a, _mm256_loadu_ps(xk.as_ptr().add(i)), *acc_k);
            }
            i += 8;
        }
        let mut out = [0.0f32; 8];
        for ((o, acc_k), xk) in out.iter_mut().zip(acc.iter()).zip(xs.iter()) {
            let mut lanes = [0.0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), *acc_k);
            let mut s: f32 = lanes.iter().sum();
            let mut j = i;
            while j < n {
                s += row[j] * xk[j];
                j += 1;
            }
            *o = s;
        }
        out
    }

    /// # Safety
    /// Every `xs[k]` must be at least `row.len()` long.
    pub(super) unsafe fn dot8_sse2(row: &[f32], xs: &[&[f32]; 8]) -> [f32; 8] {
        let n = row.len();
        let mut acc = [_mm_setzero_ps(); 8];
        let mut i = 0usize;
        while i + 4 <= n {
            let a = _mm_loadu_ps(row.as_ptr().add(i));
            for (acc_k, xk) in acc.iter_mut().zip(xs.iter()) {
                *acc_k = _mm_add_ps(*acc_k, _mm_mul_ps(a, _mm_loadu_ps(xk.as_ptr().add(i))));
            }
            i += 4;
        }
        let mut out = [0.0f32; 8];
        for ((o, acc_k), xk) in out.iter_mut().zip(acc.iter()).zip(xs.iter()) {
            let mut lanes = [0.0f32; 4];
            _mm_storeu_ps(lanes.as_mut_ptr(), *acc_k);
            let mut s: f32 = lanes.iter().sum();
            let mut j = i;
            while j < n {
                s += row[j] * xk[j];
                j += 1;
            }
            *o = s;
        }
        out
    }

    /// Sparse dot: values `vals` (global index offset `start` into the
    /// column array) against gathered `x` entries, 8 at a time.
    ///
    /// # Safety
    /// Requires AVX2; `cols` must hold at least `start + vals.len()`
    /// indices of width `idx_bytes`, each `< x.len()`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn csr_dot_avx2(
        vals: &[f32],
        cols: *const u8,
        idx_bytes: usize,
        start: usize,
        x: &[f32],
    ) -> f32 {
        let n = vals.len();
        let mut acc = _mm256_setzero_ps();
        let mut gather = [0.0f32; 8];
        let mut i = 0usize;
        while i + 8 <= n {
            for (k, g) in gather.iter_mut().enumerate() {
                *g = *x.get_unchecked(super::load_idx(cols, idx_bytes, start + i + k));
            }
            acc = _mm256_add_ps(
                acc,
                _mm256_mul_ps(_mm256_loadu_ps(vals.as_ptr().add(i)), _mm256_loadu_ps(gather.as_ptr())),
            );
            i += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut s: f32 = lanes.iter().sum();
        while i < n {
            s += vals[i] * x[super::load_idx(cols, idx_bytes, start + i)];
            i += 1;
        }
        s
    }

    /// # Safety
    /// Same index contract as [`csr_dot_avx2`]; SSE2 is baseline.
    pub(super) unsafe fn csr_dot_sse2(
        vals: &[f32],
        cols: *const u8,
        idx_bytes: usize,
        start: usize,
        x: &[f32],
    ) -> f32 {
        let n = vals.len();
        let mut acc = _mm_setzero_ps();
        let mut gather = [0.0f32; 4];
        let mut i = 0usize;
        while i + 4 <= n {
            for (k, g) in gather.iter_mut().enumerate() {
                *g = *x.get_unchecked(super::load_idx(cols, idx_bytes, start + i + k));
            }
            acc = _mm_add_ps(
                acc,
                _mm_mul_ps(_mm_loadu_ps(vals.as_ptr().add(i)), _mm_loadu_ps(gather.as_ptr())),
            );
            i += 4;
        }
        let mut lanes = [0.0f32; 4];
        _mm_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut s: f32 = lanes.iter().sum();
        while i < n {
            s += vals[i] * x[super::load_idx(cols, idx_bytes, start + i)];
            i += 1;
        }
        s
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// # Safety
    /// NEON is part of the aarch64 baseline; only the raw loads are unsafe
    /// and stay within `row`/`x` bounds.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_neon(row: &[f32], x: &[f32]) -> f32 {
        let n = row.len().min(x.len());
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            acc0 = vaddq_f32(
                acc0,
                vmulq_f32(vld1q_f32(row.as_ptr().add(i)), vld1q_f32(x.as_ptr().add(i))),
            );
            acc1 = vaddq_f32(
                acc1,
                vmulq_f32(vld1q_f32(row.as_ptr().add(i + 4)), vld1q_f32(x.as_ptr().add(i + 4))),
            );
            i += 8;
        }
        while i + 4 <= n {
            acc0 = vaddq_f32(
                acc0,
                vmulq_f32(vld1q_f32(row.as_ptr().add(i)), vld1q_f32(x.as_ptr().add(i))),
            );
            i += 4;
        }
        let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            s += row[i] * x[i];
            i += 1;
        }
        s
    }

    /// # Safety
    /// Every `xs[k]` must be at least `row.len()` long.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot8_neon(row: &[f32], xs: &[&[f32]; 8]) -> [f32; 8] {
        let n = row.len();
        let mut acc = [vdupq_n_f32(0.0); 8];
        let mut i = 0usize;
        while i + 4 <= n {
            let a = vld1q_f32(row.as_ptr().add(i));
            for (acc_k, xk) in acc.iter_mut().zip(xs.iter()) {
                *acc_k = vaddq_f32(*acc_k, vmulq_f32(a, vld1q_f32(xk.as_ptr().add(i))));
            }
            i += 4;
        }
        let mut out = [0.0f32; 8];
        for ((o, acc_k), xk) in out.iter_mut().zip(acc.iter()).zip(xs.iter()) {
            let mut s = vaddvq_f32(*acc_k);
            let mut j = i;
            while j < n {
                s += row[j] * xk[j];
                j += 1;
            }
            *o = s;
        }
        out
    }

    /// # Safety
    /// `cols` must hold at least `start + vals.len()` indices of width
    /// `idx_bytes`, each `< x.len()`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn csr_dot_neon(
        vals: &[f32],
        cols: *const u8,
        idx_bytes: usize,
        start: usize,
        x: &[f32],
    ) -> f32 {
        let n = vals.len();
        let mut acc = vdupq_n_f32(0.0);
        let mut gather = [0.0f32; 4];
        let mut i = 0usize;
        while i + 4 <= n {
            for (k, g) in gather.iter_mut().enumerate() {
                *g = *x.get_unchecked(super::load_idx(cols, idx_bytes, start + i + k));
            }
            acc = vaddq_f32(
                acc,
                vmulq_f32(vld1q_f32(vals.as_ptr().add(i)), vld1q_f32(gather.as_ptr())),
            );
            i += 4;
        }
        let mut s = vaddvq_f32(acc);
        while i < n {
            s += vals[i] * x[super::load_idx(cols, idx_bytes, start + i)];
            i += 1;
        }
        s
    }
}

// ---------------------------------------------------------------------------
// ISA-agnostic dispatch shims (one branch per *row*, on a flag hoisted out
// of the kernel loops by the drivers).
// ---------------------------------------------------------------------------

/// # Safety
/// `x.len() >= row.len()` is not required (the shorter length wins), but
/// on x86_64 `fast` must only be true when AVX2 is available and `fma`
/// only when AVX2+FMA are.
#[cfg(target_arch = "x86_64")]
#[inline]
unsafe fn row_dot(fast: bool, fma: bool, row: &[f32], x: &[f32]) -> f32 {
    if fma {
        x86::dot_avx2_fma(row, x)
    } else if fast {
        x86::dot_avx2(row, x)
    } else {
        x86::dot_sse2(row, x)
    }
}

#[cfg(target_arch = "aarch64")]
#[inline]
unsafe fn row_dot(_fast: bool, _fma: bool, row: &[f32], x: &[f32]) -> f32 {
    neon::dot_neon(row, x)
}

/// # Safety
/// Every `xs[k].len() >= row.len()`; `fast`/`fma` as in [`row_dot`].
#[cfg(target_arch = "x86_64")]
#[inline]
unsafe fn row_dot8(fast: bool, fma: bool, row: &[f32], xs: &[&[f32]; 8]) -> [f32; 8] {
    if fma {
        x86::dot8_avx2_fma(row, xs)
    } else if fast {
        x86::dot8_avx2(row, xs)
    } else {
        x86::dot8_sse2(row, xs)
    }
}

#[cfg(target_arch = "aarch64")]
#[inline]
unsafe fn row_dot8(_fast: bool, _fma: bool, row: &[f32], xs: &[&[f32]; 8]) -> [f32; 8] {
    neon::dot8_neon(row, xs)
}

/// # Safety
/// `cols` must hold `start + vals.len()` indices of width `idx_bytes`,
/// each `< x.len()`; `fast` as in [`row_dot`].
#[cfg(target_arch = "x86_64")]
#[inline]
unsafe fn csr_dot(fast: bool, vals: &[f32], cols: *const u8, idx_bytes: usize, start: usize, x: &[f32]) -> f32 {
    if fast {
        x86::csr_dot_avx2(vals, cols, idx_bytes, start, x)
    } else {
        x86::csr_dot_sse2(vals, cols, idx_bytes, start, x)
    }
}

#[cfg(target_arch = "aarch64")]
#[inline]
unsafe fn csr_dot(_fast: bool, vals: &[f32], cols: *const u8, idx_bytes: usize, start: usize, x: &[f32]) -> f32 {
    neon::csr_dot_neon(vals, cols, idx_bytes, start, x)
}

// ---------------------------------------------------------------------------
// Drivers — the entry points `AnyMatrix` dispatches to for
// `KernelBackend::Simd`. Signatures mirror the scalar kernels exactly.
// ---------------------------------------------------------------------------

/// Vectorized counterpart of `dense_k::dense_matvec_rows` (tolerance
/// contract, not bit-identity).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
pub(crate) fn dense_matvec_rows_simd(
    m: &Dense,
    rows: Range<usize>,
    x: &[f32],
    y: &mut [f32],
    epi: Option<&Epilogue<'_>>,
) {
    let (fast, fma) = (fast_isa(), fma_isa());
    for (out, r) in y.iter_mut().zip(rows) {
        // SAFETY: vector loads stay within row/x bounds (shorter length
        // wins inside the primitive); `fast`/`fma` imply the checked ISA
        // on x86_64.
        let acc = unsafe { row_dot(fast, fma, m.row(r), x) };
        *out = finish(epi, r, acc);
    }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub(crate) fn dense_matvec_rows_simd(
    m: &Dense,
    rows: Range<usize>,
    x: &[f32],
    y: &mut [f32],
    epi: Option<&Epilogue<'_>>,
) {
    super::dense_k::dense_matvec_rows(m, rows, x, y, epi);
}

/// Vectorized counterpart of `csr_k`'s row-range matvec.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
pub(crate) fn csr_matvec_rows_simd(
    m: &Csr,
    rows: Range<usize>,
    x: &[f32],
    y: &mut [f32],
    epi: Option<&Epilogue<'_>>,
) {
    let fast = fast_isa();
    let values: &[f32] = &m.values;
    let row_ptr: &[u32] = &m.row_ptr;
    with_col_indices!(&m.col_idx, ci => {
        let cols_base = slice_ptr_bytes(ci);
        let idx_bytes = elem_size(ci);
        for (out, r) in y.iter_mut().zip(rows) {
            let (s, e) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
            // SAFETY: CSR construction guarantees every column index is
            // `< cols == x.len()` and `e <= values.len() == ci.len()`.
            let acc = unsafe { csr_dot(fast, &values[s..e], cols_base, idx_bytes, s, x) };
            *out = finish(epi, r, acc);
        }
    });
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub(crate) fn csr_matvec_rows_simd(
    m: &Csr,
    rows: Range<usize>,
    x: &[f32],
    y: &mut [f32],
    epi: Option<&Epilogue<'_>>,
) {
    match epi {
        Some(e) => super::csr_k::csr_matvec_range_epi(m, rows, x, y, e),
        None => super::csr_k::csr_matvec_range(m, rows, x, y),
    }
}

/// Vectorized counterpart of `dense_k::dense_matmul_cells` with the tile
/// widened from 4 to 16/8 rhs columns.
///
/// # Safety
/// No other thread may access rows `rows` of `y` during the call (same
/// contract as the scalar kernel).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
pub(crate) unsafe fn dense_matmul_cells_simd(
    m: &Dense,
    rows: Range<usize>,
    x: &[f32],
    y: &[SyncCell],
    l: usize,
    epi: Option<&Epilogue<'_>>,
) {
    let (fast, fma) = (fast_isa(), fma_isa());
    let (m_total, n) = (m.rows(), m.cols());
    debug_assert_eq!(x.len(), n * l);
    debug_assert_eq!(y.len(), m_total * l);
    debug_assert!(rows.end <= m_total);
    let mut c = 0usize;
    while c + 16 <= l {
        let lo: [&[f32]; 8] = std::array::from_fn(|k| &x[(c + k) * n..(c + k + 1) * n]);
        let hi: [&[f32]; 8] = std::array::from_fn(|k| &x[(c + 8 + k) * n..(c + 8 + k + 1) * n]);
        for r in rows.clone() {
            let row = m.row(r);
            let a = row_dot8(fast, fma, row, &lo);
            let b = row_dot8(fast, fma, row, &hi);
            for (k, v) in a.iter().enumerate() {
                y[(c + k) * m_total + r].set(finish(epi, r, *v));
            }
            for (k, v) in b.iter().enumerate() {
                y[(c + 8 + k) * m_total + r].set(finish(epi, r, *v));
            }
        }
        c += 16;
    }
    while c + 8 <= l {
        let xs: [&[f32]; 8] = std::array::from_fn(|k| &x[(c + k) * n..(c + k + 1) * n]);
        for r in rows.clone() {
            let out = row_dot8(fast, fma, m.row(r), &xs);
            for (k, v) in out.iter().enumerate() {
                y[(c + k) * m_total + r].set(finish(epi, r, *v));
            }
        }
        c += 8;
    }
    for c in c..l {
        let seg = &y[c * m_total + rows.start..c * m_total + rows.end];
        // SAFETY: this shard exclusively owns rows `rows` of every column.
        let yc = crate::exec::cells_as_mut(seg);
        dense_matvec_rows_simd(m, rows.clone(), &x[c * n..(c + 1) * n], yc, epi);
    }
}

/// # Safety
/// Same contract as `dense_k::dense_matmul_cells`.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub(crate) unsafe fn dense_matmul_cells_simd(
    m: &Dense,
    rows: Range<usize>,
    x: &[f32],
    y: &[SyncCell],
    l: usize,
    epi: Option<&Epilogue<'_>>,
) {
    super::dense_k::dense_matmul_cells(m, rows, x, y, l, epi);
}

/// Vectorized counterpart of `csr_k::csr_matmul_cells` with the tile
/// widened from 4 to 8 rhs columns (one value/index stream pass per 8
/// samples, each column's dot vectorized along the non-zeros).
///
/// # Safety
/// No other thread may access rows `rows` of `y` during the call.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
pub(crate) unsafe fn csr_matmul_cells_simd(
    m: &Csr,
    rows: Range<usize>,
    x: &[f32],
    y: &[SyncCell],
    l: usize,
    epi: Option<&Epilogue<'_>>,
) {
    let fast = fast_isa();
    let (m_total, n) = (m.rows(), m.cols());
    debug_assert_eq!(x.len(), n * l);
    debug_assert_eq!(y.len(), m_total * l);
    debug_assert!(rows.end <= m_total);
    let values: &[f32] = &m.values;
    let row_ptr: &[u32] = &m.row_ptr;
    with_col_indices!(&m.col_idx, ci => {
        let cols_base = slice_ptr_bytes(ci);
        let idx_bytes = elem_size(ci);
        let mut c = 0usize;
        while c + 8 <= l {
            let xs: [&[f32]; 8] = std::array::from_fn(|k| &x[(c + k) * n..(c + k + 1) * n]);
            for r in rows.clone() {
                let (s, e) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
                let vals = &values[s..e];
                for (k, xk) in xs.iter().enumerate() {
                    let acc = csr_dot(fast, vals, cols_base, idx_bytes, s, xk);
                    y[(c + k) * m_total + r].set(finish(epi, r, acc));
                }
            }
            c += 8;
        }
        for c in c..l {
            let seg = &y[c * m_total + rows.start..c * m_total + rows.end];
            // SAFETY: this shard exclusively owns rows `rows` of every
            // column.
            let yc = crate::exec::cells_as_mut(seg);
            csr_matvec_rows_simd(m, rows.clone(), &x[c * n..(c + 1) * n], yc, epi);
        }
    });
}

/// # Safety
/// Same contract as `csr_k::csr_matmul_cells`.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub(crate) unsafe fn csr_matmul_cells_simd(
    m: &Csr,
    rows: Range<usize>,
    x: &[f32],
    y: &[SyncCell],
    l: usize,
    epi: Option<&Epilogue<'_>>,
) {
    super::csr_k::csr_matmul_cells(m, rows, x, y, l, epi);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{csr_matvec, dense_matvec};
    use crate::util::Rng;

    fn assert_close(got: &[f32], want: &[f32]) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            let tol = 1e-5 + 1e-4 * w.abs();
            assert!((g - w).abs() <= tol, "idx {i}: {g} vs {w}");
        }
    }

    fn random_dense(rows: usize, cols: usize, seed: u64) -> Dense {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..rows * cols)
            .map(|_| {
                if rng.below(3) == 0 {
                    0.0
                } else {
                    rng.f32() * 2.0 - 1.0
                }
            })
            .collect();
        Dense::from_vec(rows, cols, data)
    }

    #[test]
    fn dense_matvec_matches_scalar_within_tolerance() {
        for cols in [1usize, 3, 7, 8, 17, 64, 100] {
            let m = random_dense(9, cols, 0x51D + cols as u64);
            let x: Vec<f32> = (0..cols).map(|i| (i as f32) * 0.17 - 1.2).collect();
            let mut want = vec![0.0; 9];
            dense_matvec(&m, &x, &mut want);
            let mut got = vec![0.0; 9];
            dense_matvec_rows_simd(&m, 0..9, &x, &mut got, None);
            assert_close(&got, &want);
        }
    }

    #[test]
    fn csr_matvec_matches_scalar_within_tolerance() {
        for cols in [5usize, 40, 300] {
            let m = random_dense(11, cols, 0xC5A + cols as u64);
            let csr = Csr::from_dense(&m);
            let x: Vec<f32> = (0..cols).map(|i| (i as f32) * 0.05 - 0.7).collect();
            let mut want = vec![0.0; 11];
            csr_matvec(&csr, &x, &mut want);
            let mut got = vec![0.0; 11];
            csr_matvec_rows_simd(&csr, 0..11, &x, &mut got, None);
            assert_close(&got, &want);
        }
    }

    #[test]
    fn wide_tiles_match_per_column_matvec() {
        let m = random_dense(6, 33, 0x71E);
        let csr = Csr::from_dense(&m);
        for l in [1usize, 7, 8, 9, 16, 17, 24] {
            let mut rng = Rng::new(l as u64 + 1);
            let x: Vec<f32> = (0..33 * l).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let bias: Vec<f32> = (0..6).map(|r| r as f32 * 0.25 - 0.5).collect();
            for relu in [false, true] {
                let epi = Epilogue { bias: &bias, relu };
                let mut dense_got = vec![0.0; 6 * l];
                let cells = crate::exec::as_cells(&mut dense_got);
                // SAFETY: exclusively borrowed output, single caller.
                unsafe { dense_matmul_cells_simd(&m, 0..6, &x, cells, l, Some(&epi)) };
                let mut csr_got = vec![0.0; 6 * l];
                let cells = crate::exec::as_cells(&mut csr_got);
                // SAFETY: exclusively borrowed output, single caller.
                unsafe { csr_matmul_cells_simd(&csr, 0..6, &x, cells, l, Some(&epi)) };
                for c in 0..l {
                    let mut want = vec![0.0; 6];
                    dense_matvec(&m, &x[c * 33..(c + 1) * 33], &mut want);
                    for (r, v) in want.iter_mut().enumerate() {
                        *v += bias[r];
                        if relu && *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                    assert_close(&dense_got[c * 6..(c + 1) * 6], &want);
                    assert_close(&csr_got[c * 6..(c + 1) * 6], &want);
                }
            }
        }
    }
}
