//! Algorithm 2 — CSR dot product: multiply-add over the non-zero entries.

use crate::formats::Csr;
use crate::formats::index::Idx;
use crate::with_col_indices;

/// `y = M·x` over the CSR representation.
pub fn csr_matvec(m: &Csr, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), m.cols(), "x length");
    assert_eq!(y.len(), m.rows(), "y length");
    with_col_indices!(&m.col_idx, ci => csr_matvec_inner(&m.values, ci, &m.row_ptr, x, y));
}

fn csr_matvec_inner<I: Idx>(
    values: &[f32],
    col_idx: &[I],
    row_ptr: &[u32],
    x: &[f32],
    y: &mut [f32],
) {
    for (r, out) in y.iter_mut().enumerate() {
        let (s, e) = (row_ptr[r] as usize, row_ptr[r + 1] as usize);
        // Two independent FMA chains + bounds-check elision (§Perf
        // iteration 1); construction guarantees col_idx[i] < cols ==
        // x.len() and values/col_idx have equal length.
        let (vals, cols) = (&values[s..e], &col_idx[s..e]);
        let mut acc0 = 0.0f32;
        let mut acc1 = 0.0f32;
        let mut vch = vals.chunks_exact(2);
        let mut cch = cols.chunks_exact(2);
        for (v2, c2) in vch.by_ref().zip(cch.by_ref()) {
            debug_assert!(c2.iter().all(|c| c.to_usize() < x.len()));
            unsafe {
                acc0 += v2[0] * *x.get_unchecked(c2[0].to_usize());
                acc1 += v2[1] * *x.get_unchecked(c2[1].to_usize());
            }
        }
        for (v, c) in vch.remainder().iter().zip(cch.remainder()) {
            acc0 += v * x[c.to_usize()];
        }
        *out = acc0 + acc1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Dense;
    use crate::paper_example_matrix;

    #[test]
    fn paper_row2_uses_only_nonzeros() {
        // §III-B CSR expression: 4a1+4a2+4a6+4a9+4a10+4a12.
        let csr = Csr::from_dense(&paper_example_matrix());
        let x: Vec<f32> = (1..=12).map(|i| i as f32).collect();
        let mut y = vec![0.0; 5];
        csr_matvec(&csr, &x, &mut y);
        assert_eq!(y[1], 4.0 * (1.0 + 2.0 + 6.0 + 9.0 + 10.0 + 12.0));
    }

    #[test]
    fn empty_rows_produce_zero() {
        let m = Dense::from_rows(&[vec![0.0, 0.0], vec![1.0, 0.0]]);
        let csr = Csr::from_dense(&m);
        let mut y = vec![7.0; 2];
        csr_matvec(&csr, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![0.0, 3.0]);
    }
}
