//! Algorithm 4 — CSER dot product.
//!
//! Identical to the CER kernel except each run's value is named explicitly
//! by the `ΩI` array (`omega[omega_idx[slot]]`) instead of positionally.

use crate::formats::Cser;
use crate::formats::index::Idx;
use crate::with_col_indices;

/// `y = M·x` over the CSER representation.
pub fn cser_matvec(m: &Cser, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), m.cols(), "x length");
    assert_eq!(y.len(), m.rows(), "y length");
    let w0 = m.omega[0];
    let sum_x: f32 = if w0 != 0.0 { x.iter().sum() } else { 0.0 };
    with_col_indices!(&m.col_idx, ci => cser_matvec_inner(m, ci, x, y, w0, sum_x));
}

fn cser_matvec_inner<I: Idx>(
    m: &Cser,
    col_idx: &[I],
    x: &[f32],
    y: &mut [f32],
    w0: f32,
    sum_x: f32,
) {
    let omega = &m.omega;
    let omega_idx = &m.omega_idx;
    let omega_ptr = &m.omega_ptr;
    if w0 == 0.0 {
        // Hot path (decomposed matrices) — see cer_k::gather_sum.
        for (r, out) in y.iter_mut().enumerate() {
            let (s, e) = m.row_runs(r);
            let mut acc = 0.0f32;
            let mut start = omega_ptr[s] as usize;
            for slot in s..e {
                let end = omega_ptr[slot + 1] as usize;
                acc += super::cer_k::gather_sum(&col_idx[start..end], x)
                    * omega[omega_idx[slot] as usize];
                start = end;
            }
            *out = acc;
        }
        return;
    }
    for (r, out) in y.iter_mut().enumerate() {
        let (s, e) = m.row_runs(r);
        let mut acc = 0.0f32;
        let mut listed = 0.0f32;
        let mut start = omega_ptr[s] as usize;
        for slot in s..e {
            let end = omega_ptr[slot + 1] as usize;
            let partial = super::cer_k::gather_sum(&col_idx[start..end], x);
            acc += partial * omega[omega_idx[slot] as usize];
            listed += partial;
            start = end;
        }
        acc += w0 * (sum_x - listed);
        *out = acc;
    }
}

/// `Y = M·X` over CSER with `X` column-major (n × l): four rhs columns per
/// pass (see `cer_k::gather_sum4`).
pub fn cser_matmul_colmajor(m: &Cser, x: &[f32], y: &mut [f32], l: usize) {
    let (rows, n) = (m.rows(), m.cols());
    assert_eq!(x.len(), n * l, "rhs shape");
    assert_eq!(y.len(), rows * l, "out shape");
    let w0 = m.omega[0];
    let mut c = 0usize;
    while c + 4 <= l {
        with_col_indices!(&m.col_idx, ci => {
            let xs: [&[f32]; 4] = [
                &x[c * n..(c + 1) * n],
                &x[(c + 1) * n..(c + 2) * n],
                &x[(c + 2) * n..(c + 3) * n],
                &x[(c + 3) * n..(c + 4) * n],
            ];
            cser_matmul4_inner(m, ci, &xs, y, c, w0);
        });
        c += 4;
    }
    for c in c..l {
        let (xc, yc) = (&x[c * n..(c + 1) * n], &mut y[c * rows..(c + 1) * rows]);
        cser_matvec(m, xc, yc);
    }
}

fn cser_matmul4_inner<I: Idx>(
    m: &Cser,
    col_idx: &[I],
    xs: &[&[f32]; 4],
    y: &mut [f32],
    c: usize,
    w0: f32,
) {
    let rows = m.rows();
    let omega = &m.omega;
    let omega_idx = &m.omega_idx;
    let omega_ptr = &m.omega_ptr;
    let sum_x: [f32; 4] = if w0 != 0.0 {
        [
            xs[0].iter().sum(),
            xs[1].iter().sum(),
            xs[2].iter().sum(),
            xs[3].iter().sum(),
        ]
    } else {
        [0.0; 4]
    };
    for r in 0..rows {
        let (s, e) = m.row_runs(r);
        let mut acc = [0.0f32; 4];
        let mut listed = [0.0f32; 4];
        let mut start = omega_ptr[s] as usize;
        for slot in s..e {
            let end = omega_ptr[slot + 1] as usize;
            let p = super::cer_k::gather_sum4(&col_idx[start..end], xs);
            let w = omega[omega_idx[slot] as usize];
            for lane in 0..4 {
                acc[lane] += p[lane] * w;
                listed[lane] += p[lane];
            }
            start = end;
        }
        for lane in 0..4 {
            let mut v = acc[lane];
            if w0 != 0.0 {
                v += w0 * (sum_x[lane] - listed[lane]);
            }
            y[(c + lane) * rows + r] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Dense;
    use crate::paper_example_matrix;

    #[test]
    fn paper_row2_distributive_form() {
        let cser = Cser::from_dense(&paper_example_matrix());
        let x: Vec<f32> = (1..=12).map(|i| i as f32).collect();
        let mut y = vec![0.0; 5];
        cser_matvec(&cser, &x, &mut y);
        assert_eq!(y[1], 4.0 * 40.0);
    }

    #[test]
    fn row_local_orderings() {
        let m = Dense::from_rows(&[
            vec![0.0, 1.0, 1.0, 2.0],
            vec![0.0, 2.0, 2.0, 1.0],
        ]);
        let cser = Cser::from_dense(&m);
        let x = vec![1.0, 10.0, 100.0, 1000.0];
        let mut y = vec![0.0; 2];
        cser_matvec(&cser, &x, &mut y);
        assert_eq!(y, vec![110.0 + 2000.0, 220.0 + 1000.0]);
    }

    #[test]
    fn correction_term_for_nonzero_implicit() {
        let m = Dense::from_rows(&[vec![3.0, 3.0, 0.0, 1.0]]);
        let cser = Cser::from_dense(&m);
        assert_eq!(cser.omega[0], 3.0);
        let x = vec![1.0, 2.0, 4.0, 8.0];
        let mut y = vec![0.0; 1];
        cser_matvec(&cser, &x, &mut y);
        assert_eq!(y[0], 3.0 + 6.0 + 0.0 + 8.0);
    }
}
