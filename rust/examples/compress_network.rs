//! Compress a whole network with the paper's §V-C pipeline
//! (prune → cluster), auto-select a format per layer, and report the
//! compression / efficiency gains — the workflow a deployment would run.
//!
//! ```sh
//! cargo run --release --example compress_network [-- <net> [keep] [clusters]]
//! # e.g.  cargo run --release --example compress_network -- lenet5 0.05 8
//! ```

use cer::compress::pipeline::CompressionPipeline;
use cer::coordinator::{select_format, Objective};
use cer::costmodel::{trace_matvec, EnergyModel, TimeModel};
use cer::formats::{FormatKind, MatrixFormat};
use cer::kernels::AnyMatrix;
use cer::networks::weights::synthesize_float_layer;
use cer::networks::zoo::NetworkSpec;
use cer::util::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let net = args.first().map(String::as_str).unwrap_or("lenet-300-100");
    let keep: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.09);
    let clusters: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);

    let spec = NetworkSpec::by_name(net).unwrap_or_else(|| {
        eprintln!("unknown network '{net}', using LeNet-300-100");
        NetworkSpec::lenet_300_100()
    });
    println!(
        "{}: {} layers, {:.2} MB dense; pipeline: keep {:.1}% + {clusters}-means\n",
        spec.name,
        spec.layers.len(),
        spec.dense_mb(),
        keep * 100.0
    );

    let energy = EnergyModel::table_i();
    let time = TimeModel::default_model();
    let pipeline = CompressionPipeline::deep_compression(keep, clusters);
    let mut rng = Rng::new(7);

    let (mut dense_bits, mut best_bits) = (0u64, 0u64);
    let (mut dense_pj, mut best_pj) = (0.0f64, 0.0f64);
    println!(
        "{:<22} {:>6} {:>8} {:>8} {:>8}  {:>7}",
        "layer", "p0", "H", "kbar", "format", "gain"
    );
    for l in &spec.layers {
        let w = synthesize_float_layer(l, 0.05, 0.05, 4.0, &mut rng);
        let r = pipeline.run(&w);
        let (kind, crits) = select_format(&r.compressed, &energy, &time, Objective::Energy);
        let enc = AnyMatrix::encode(kind, &r.compressed);
        let s = &r.stats;
        let winner_idx = FormatKind::ALL.iter().position(|&k| k == kind).unwrap();
        let gain = crits[0].energy_pj / crits[winner_idx].energy_pj;
        println!(
            "{:<22} {:>6.3} {:>8.3} {:>8.2} {:>8}  x{:<6.2}",
            l.name,
            s.p0,
            s.entropy,
            s.kbar,
            kind.name(),
            gain
        );
        dense_bits += (l.rows * l.cols) as u64 * 32;
        best_bits += enc.storage().total_bits();
        let trace = trace_matvec(&enc);
        let dense_trace = trace_matvec(&AnyMatrix::encode(FormatKind::Dense, &r.compressed));
        dense_pj += dense_trace.energy_pj(&energy) * l.patches as f64;
        best_pj += trace.energy_pj(&energy) * l.patches as f64;
    }
    println!(
        "\nnetwork totals: storage x{:.2} ({:.2} MB → {:.2} MB), energy x{:.2} per inference",
        dense_bits as f64 / best_bits as f64,
        dense_bits as f64 / 8e6,
        best_bits as f64 / 8e6,
        dense_pj / best_pj,
    );
}
