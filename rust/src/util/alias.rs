//! Walker's alias method for O(1) sampling from a discrete distribution.
//!
//! The (H, p₀)-plane experiments of the paper (Figs. 4 and 5) sample tens of
//! millions of matrix elements from synthesized probability mass functions;
//! the alias table makes this O(1) per element after O(K) setup.

use super::rng::Rng;

/// Precomputed alias table over `K` outcomes.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from (not necessarily normalized) non-negative weights.
    ///
    /// Panics if `weights` is empty, contains a negative/NaN value, or sums
    /// to zero.
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty(), "alias table needs at least one outcome");
        let sum: f64 = weights.iter().sum();
        assert!(
            sum > 0.0 && sum.is_finite(),
            "weights must sum to a positive finite value"
        );
        for &w in weights {
            assert!(w >= 0.0, "negative weight {w}");
        }
        let k = weights.len();
        let mut prob: Vec<f64> = weights.iter().map(|&w| w / sum * k as f64).collect();
        let mut alias = vec![0u32; k];
        let mut small: Vec<u32> = Vec::with_capacity(k);
        let mut large: Vec<u32> = Vec::with_capacity(k);
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(s), Some(l)) = (small.pop(), large.pop()) {
            alias[s as usize] = l;
            prob[l as usize] = (prob[l as usize] + prob[s as usize]) - 1.0;
            if prob[l as usize] < 1.0 {
                small.push(l);
            } else {
                large.push(l);
            }
        }
        // Numerical leftovers: force to 1.
        for i in small.into_iter().chain(large) {
            prob[i as usize] = 1.0;
        }
        AliasTable { prob, alias }
    }

    /// Number of outcomes.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Draw one outcome index.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.below(self.prob.len());
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_target_distribution() {
        let weights = [0.5, 0.25, 0.125, 0.125];
        let t = AliasTable::new(&weights);
        let mut rng = Rng::new(123);
        let n = 400_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            counts[t.sample(&mut rng)] += 1;
        }
        for (i, &w) in weights.iter().enumerate() {
            let emp = counts[i] as f64 / n as f64;
            assert!(
                (emp - w).abs() < 0.005,
                "outcome {i}: empirical {emp} vs target {w}"
            );
        }
    }

    #[test]
    fn single_outcome() {
        let t = AliasTable::new(&[3.0]);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(t.sample(&mut rng), 0);
        }
    }

    #[test]
    fn zero_weight_never_sampled() {
        let t = AliasTable::new(&[1.0, 0.0, 1.0]);
        let mut rng = Rng::new(5);
        for _ in 0..50_000 {
            assert_ne!(t.sample(&mut rng), 1);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_empty() {
        AliasTable::new(&[]);
    }

    #[test]
    #[should_panic]
    fn rejects_all_zero() {
        AliasTable::new(&[0.0, 0.0]);
    }
}
