"""L1 kernels: Pallas implementations + pure-jnp oracles."""

from .cser_mv import cser_matmul, vmem_footprint_bytes
from .ref import cser_matmul_ref, decode, quantized_matmul_ref

__all__ = [
    "cser_matmul",
    "cser_matmul_ref",
    "decode",
    "quantized_matmul_ref",
    "vmem_footprint_bytes",
]
