//! `cerpack` — the native on-disk artifact format for compressed networks.
//!
//! The paper's deliverable is not a measurement but an artifact: a network
//! whose layers are stored in their entropy-optimal representations. This
//! module serializes a whole compressed network — every layer's
//! [`AnyMatrix`] payload in its *selected* format (dense/CSR/CER/CSER/
//! BSR/TNN with codebooks and index-width tags), biases, topology, and a
//! provenance
//! manifest — into a single versioned `.cerpack` file, and loads it back
//! without re-running pruning, clustering, encoding or format selection
//! (the engine cold-start path, [`crate::coordinator::PackOptions`]).
//!
//! Two readers share the wire format and every validation rule:
//! [`Pack::from_bytes`] copies each array into owned storage, while
//! [`Pack::from_map`] (and [`Pack::open_mapped`] / the engine's
//! `PackOptions::new(path).mmap(true).open()`) decodes over a shared
//! [`map::PackMap`] and hands back zero-copy [`crate::formats::Storage`]
//! views — the arrays are already written little-endian at their natural
//! alignment, so no per-array heap copy is made and any number of
//! engines can serve from one reference-counted mapping.
//!
//! # Wire layout (version 1, all integers little-endian)
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"CERPACK\0"
//! 8       2     version (= 1)
//! 10      2     flags   (bit 0 = entropy-coded sections present;
//!                        all other bits reserved, rejected)
//! 12      4     section count  (u32)
//! 16      24×n  section table, one entry per section:
//!                   u32 kind        1 = manifest, 2 = layer,
//!                                   3 = codebooks, 4 = coded layer
//!                   u32 crc32       CRC-32 (IEEE) of the raw section bytes
//!                   u64 offset      absolute file offset (8-byte aligned)
//!                   u64 len         section byte length (before padding)
//! ...           sections, each zero-padded to an 8-byte boundary
//! ```
//!
//! The first **table entry** is the **manifest** (exactly one per file);
//! the layer entries follow in forward layer order. Physical section
//! order in the file is unconstrained — the streaming writer
//! ([`stream::PackWriter`]) appends layers first and the manifest last.
//!
//! ## Storage tiers
//!
//! A layer section comes in two tiers, chosen per layer at write time:
//!
//! * **raw** (kind 2) — arrays at their accounted minimal widths, laid
//!   out at natural alignment so the mapped reader can view them
//!   zero-copy in place;
//! * **coded** (kind 4) — the same payload split into streams, with
//!   every integer array stream canonically Huffman-coded when that is
//!   smaller than raw (see [`entropy`]); float arrays and structural
//!   bytes pass through verbatim. A coded layer decodes **once at load**
//!   into owned storage and stays coded on disk — closing the gap
//!   between minimal-width bytes and the paper's `N·H` entropy bound.
//!   Length tables are deduplicated pack-wide in a single codebooks
//!   section (kind 3) and referenced by id.
//!
//! Readers predating the entropy tier reject coded packs cleanly via the
//! header flag bit ("unsupported flags"); this reader rejects unknown
//! flag bits and unknown per-section tier bits the same way.
//!
//! ## Manifest section
//!
//! Strings are `u32` byte-length + UTF-8. Per file: `network` name,
//! `created_by` tool string, `u32` layer count; then per layer: name,
//! `u8` format tag (0 dense, 1 CSR, 2 CER, 3 CSER, 4 BSR, 5 TNN),
//! `u32` rows, `u32`
//! cols, `u32` codebook size K, `f64` entropy H (bits), `f64` p₀,
//! `u64` analytic storage bits ([`crate::formats::StorageBreakdown`]),
//! `u64` measured matrix-array bytes, `u64` total payload bytes, and a
//! free-form selection-rationale string. The manifest is self-contained:
//! everything `repro inspect` tabulates comes from it, without touching
//! the matrix payloads.
//!
//! ## Layer section
//!
//! Layer name (padded to 4), `u32` bias length, bias `f32`s, `u64`
//! payload length, then the [`AnyMatrix`] payload: a `u8` format tag plus
//! 3 reserved bytes, followed by the format's own encoding (see
//! `encode_into`/`decode_from` on [`crate::formats::Dense`],
//! [`crate::formats::Csr`], [`crate::formats::Cer`],
//! [`crate::formats::Cser`], [`crate::formats::Bsr`],
//! [`crate::formats::Tnn`]). Format payloads write their bulk arrays
//! widest-element-first (f32/u32, then u16, then u8) with explicit padding
//! so every array starts naturally aligned at its element size — a
//! decoder may reinterpret them in place. Pointer and index arrays are
//! stored at the same minimal {8,16,32}-bit widths the paper's storage
//! accounting uses, so the measured array bytes on disk equal the
//! analytic [`crate::formats::StorageBreakdown`] bits to the byte.
//!
//! # Integrity
//!
//! Every section carries a CRC-32; readers verify it before parsing, so a
//! flipped byte surfaces as [`PackError::ChecksumMismatch`], a truncated
//! file as [`PackError::Truncated`], and a foreign file as
//! [`PackError::BadMagic`] — never a panic or garbage weights. All decode
//! paths are bounds-checked and validate structural invariants (monotone
//! pointer arrays, in-range column indices and codebook references).

pub mod entropy;
pub mod map;
pub mod stream;
pub mod wire;

use std::fmt;
use std::fs;
use std::io;
use std::path::Path;
use std::sync::Arc;

use crate::formats::{FormatKind, Storage};
use crate::kernels::AnyMatrix;
use crate::util::crc32::crc32;
use map::PackMap;
use wire::{put_f32_array, put_f64, put_string, put_u16, put_u32, put_u64, ArrayLoader, Cursor};

/// File magic, 8 bytes.
pub const MAGIC: [u8; 8] = *b"CERPACK\0";
/// Container version this build writes and reads.
pub const VERSION: u16 = 1;
/// Section kind: provenance manifest (exactly one, first table entry).
pub const SECTION_MANIFEST: u32 = 1;
/// Section kind: one encoded layer (raw tier).
pub const SECTION_LAYER: u32 = 2;
/// Section kind: the pack-wide deduplicated Huffman code books (at most
/// one; present only in entropy-coded packs).
pub const SECTION_CODEBOOKS: u32 = 3;
/// Section kind: one entropy-coded layer (coded tier; see [`entropy`]).
pub const SECTION_LAYER_CODED: u32 = 4;

/// Header flag bit: the pack contains entropy-coded sections. Readers
/// predating the coded tier reject the whole file on this bit — they can
/// never misparse a coded section as raw.
pub const FLAG_ENTROPY: u16 = 0x0001;
/// Coded-section tier bit: canonical Huffman streams. Any other tier bit
/// is from a future writer and rejected.
pub const TIER_HUFFMAN: u32 = 0x0000_0001;

const HEADER_BYTES: usize = 16;
const TABLE_ENTRY_BYTES: usize = 24;
/// Upper bound on the section count a reader will accept (corrupt headers
/// must not drive huge allocations).
const MAX_SECTIONS: u32 = 1 << 20;

/// Measured-vs-analytic divergence (in percent) above which `repro
/// inspect` and the harness tables flag a layer/network — on-disk bytes
/// and the storage model must agree.
pub const DIVERGENCE_FLAG_PCT: f64 = 5.0;

/// Relative divergence of measured bytes vs analytic bits, in percent
/// (positive = disk larger than the model; 0 when the model is empty).
pub fn divergence_pct(measured_bytes: u64, analytic_bits: u64) -> f64 {
    if analytic_bits == 0 {
        return 0.0;
    }
    (measured_bytes as f64 * 8.0 / analytic_bits as f64 - 1.0) * 100.0
}

/// Everything that can go wrong reading or writing a `.cerpack`.
#[derive(Debug)]
pub enum PackError {
    /// Underlying filesystem error.
    Io(io::Error),
    /// The file does not start with [`MAGIC`].
    BadMagic,
    /// The container version is newer than this build understands.
    UnsupportedVersion(u16),
    /// A section's stored CRC-32 does not match its bytes.
    ChecksumMismatch {
        /// Index of the failing section in the section table.
        section: usize,
    },
    /// The buffer/file ended before a read completed.
    Truncated,
    /// Structurally invalid content (bad tags, non-monotone pointers,
    /// out-of-range indices, ...).
    Malformed(String),
}

impl PackError {
    pub(crate) fn malformed(msg: impl Into<String>) -> PackError {
        PackError::Malformed(msg.into())
    }
}

impl fmt::Display for PackError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PackError::Io(e) => write!(f, "I/O error: {e}"),
            PackError::BadMagic => write!(f, "not a cerpack file (bad magic)"),
            PackError::UnsupportedVersion(v) => {
                write!(f, "unsupported cerpack version {v} (this build reads {VERSION})")
            }
            PackError::ChecksumMismatch { section } => {
                write!(f, "checksum mismatch in section {section} (corrupted file)")
            }
            PackError::Truncated => write!(f, "unexpected end of file (truncated cerpack)"),
            PackError::Malformed(msg) => write!(f, "malformed cerpack: {msg}"),
        }
    }
}

impl std::error::Error for PackError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PackError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for PackError {
    fn from(e: io::Error) -> PackError {
        PackError::Io(e)
    }
}

/// Byte accounting returned by the `encode_into` codecs.
///
/// `arrays` counts only the bulk matrix arrays (values, codebook, column
/// indices, pointers) — the bytes the paper's storage model accounts for.
/// `total` additionally includes the fixed structural header (dims, tags,
/// counts) and alignment padding.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Emitted {
    pub total: usize,
    pub arrays: usize,
}

/// Per-layer provenance recorded in the manifest: why this layer looks the
/// way it does on disk, and how its measured footprint compares to the
/// analytic model.
#[derive(Clone, Debug)]
pub struct LayerProvenance {
    pub name: String,
    /// The selected representation of this layer.
    pub format: FormatKind,
    pub rows: u32,
    pub cols: u32,
    /// Distinct element values K.
    pub k: u32,
    /// Empirical element entropy H (bits).
    pub entropy: f64,
    /// Mass of the most frequent element (sparsity after decomposition).
    pub p0: f64,
    /// Analytic storage bound of the selected format, in bits
    /// ([`crate::formats::StorageBreakdown::total_bits`]).
    pub analytic_bits: u64,
    /// Measured on-disk bytes of the matrix arrays (excludes the ~50-byte
    /// structural record header; directly comparable to `analytic_bits`).
    pub array_bytes: u64,
    /// Total payload bytes including the structural header and padding.
    pub payload_bytes: u64,
    /// Free-form note on how the format was chosen.
    pub rationale: String,
}

impl LayerProvenance {
    /// Relative divergence of measured array bytes vs the analytic bits,
    /// in percent (positive = disk larger than the model).
    pub fn divergence_pct(&self) -> f64 {
        divergence_pct(self.array_bytes, self.analytic_bits)
    }
}

/// The provenance manifest: one record per layer plus file-level metadata.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Network name (zoo name or caller-supplied).
    pub network: String,
    /// Tool string, e.g. `cer 0.2.0 repro pack`.
    pub created_by: String,
    pub layers: Vec<LayerProvenance>,
}

impl Manifest {
    /// Sum of analytic bits across layers.
    pub fn total_analytic_bits(&self) -> u64 {
        self.layers.iter().map(|l| l.analytic_bits).sum()
    }

    /// Sum of measured matrix-array bytes across layers.
    pub fn total_array_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.array_bytes).sum()
    }

    /// Dense f32 baseline bytes for the packed shapes.
    pub fn dense_baseline_bytes(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.rows as u64 * l.cols as u64 * 4)
            .sum()
    }

    /// Network-level measured-vs-analytic divergence in percent.
    pub fn total_divergence_pct(&self) -> f64 {
        divergence_pct(self.total_array_bytes(), self.total_analytic_bits())
    }
}

/// One layer as stored: name, encoded matrix, bias. Matrix arrays and
/// bias are [`Storage`]-backed: owned when decoded from bytes, zero-copy
/// views when the pack was opened through [`Pack::from_map`].
#[derive(Clone, Debug)]
pub struct PackLayer {
    pub name: String,
    pub matrix: AnyMatrix,
    pub bias: Storage<f32>,
}

impl PackLayer {
    fn view(&self) -> LayerView<'_> {
        LayerView {
            name: &self.name,
            matrix: &self.matrix,
            bias: &self.bias,
        }
    }
}

/// Borrowed view of one layer for serialization — lets callers that
/// already own encoded layers (e.g. the engine) write a `.cerpack`
/// without cloning the whole network into a [`Pack`] first.
#[derive(Clone, Copy, Debug)]
pub struct LayerView<'a> {
    pub name: &'a str,
    pub matrix: &'a AnyMatrix,
    pub bias: &'a [f32],
}

/// Build a provenance manifest for borrowed layers (measured byte fields
/// are placeholders until [`serialize`] fills them).
pub fn build_manifest(network: &str, rationale: &str, layers: &[LayerView<'_>]) -> Manifest {
    Manifest {
        network: network.to_string(),
        created_by: format!("cer {} cerpack v{VERSION}", env!("CARGO_PKG_VERSION")),
        layers: layers
            .iter()
            .map(|l| {
                let (k, p0, entropy) = element_stats(l.matrix);
                LayerProvenance {
                    name: l.name.to_string(),
                    format: l.matrix.kind(),
                    rows: l.matrix.rows() as u32,
                    cols: l.matrix.cols() as u32,
                    k: k as u32,
                    entropy,
                    p0,
                    analytic_bits: l.matrix.storage().total_bits(),
                    array_bytes: 0,
                    payload_bytes: 0,
                    rationale: rationale.to_string(),
                }
            })
            .collect(),
    }
}

/// Encode one layer into a raw-tier section body. Returns the section
/// bytes and the payload's byte accounting; the payload itself is the
/// trailing `emitted.total` bytes of the section.
pub(crate) fn encode_layer_section(layer: &LayerView<'_>) -> (Vec<u8>, Emitted) {
    let mut payload = Vec::new();
    let emitted = layer.matrix.encode_into(&mut payload);
    debug_assert_eq!(emitted.total, payload.len());
    let mut sec = Vec::new();
    put_string(&mut sec, layer.name);
    wire::pad_to(&mut sec, 4);
    put_u32(&mut sec, layer.bias.len() as u32);
    put_f32_array(&mut sec, layer.bias);
    put_u64(&mut sec, payload.len() as u64);
    sec.extend_from_slice(&payload);
    (sec, emitted)
}

/// Encode one layer into a coded-tier section body: tier word, name,
/// bias, declared payload length, then the entropy-coded stream list
/// (new code books are interned into `books`). Returns the section bytes
/// plus the coded accounting (on-disk array bytes, Huffman stream
/// count).
pub(crate) fn encode_coded_layer_section(
    layer: &LayerView<'_>,
    payload: &[u8],
    books: &mut entropy::CodebookSet,
) -> Result<(Vec<u8>, u64, usize), PackError> {
    let enc = entropy::encode_streams(payload, books)?;
    let mut sec = Vec::new();
    put_u32(&mut sec, TIER_HUFFMAN);
    put_string(&mut sec, layer.name);
    wire::pad_to(&mut sec, 4);
    put_u32(&mut sec, layer.bias.len() as u32);
    put_f32_array(&mut sec, layer.bias);
    put_u64(&mut sec, payload.len() as u64);
    sec.extend_from_slice(&enc.bytes);
    Ok((sec, enc.array_disk_bytes, enc.coded_streams))
}

/// Serialize borrowed layers under `manifest` into a `.cerpack` file
/// image. Returns the bytes and the manifest as written (measured byte
/// counts filled in).
pub fn serialize(manifest: &Manifest, layers: &[LayerView<'_>]) -> (Vec<u8>, Manifest) {
    assert_eq!(
        manifest.layers.len(),
        layers.len(),
        "manifest/layer count mismatch"
    );
    // Encode layer sections first to measure payload sizes.
    let mut manifest = manifest.clone();
    let mut layer_sections: Vec<Vec<u8>> = Vec::with_capacity(layers.len());
    for (layer, prov) in layers.iter().zip(&mut manifest.layers) {
        let (sec, emitted) = encode_layer_section(layer);
        prov.array_bytes = emitted.arrays as u64;
        prov.payload_bytes = emitted.total as u64;
        layer_sections.push(sec);
    }
    let manifest_section = encode_manifest(&manifest);

    // Assemble: header, table, 8-aligned sections.
    let n_sections = 1 + layer_sections.len();
    let mut offset = HEADER_BYTES + n_sections * TABLE_ENTRY_BYTES;
    offset = (offset + 7) & !7;
    let mut table: Vec<(u32, u32, u64, u64)> = Vec::with_capacity(n_sections);
    let mut place = |kind: u32, sec: &[u8]| {
        let entry = (kind, crc32(sec), offset as u64, sec.len() as u64);
        offset = (offset + sec.len() + 7) & !7;
        entry
    };
    table.push(place(SECTION_MANIFEST, &manifest_section));
    for sec in &layer_sections {
        table.push(place(SECTION_LAYER, sec));
    }

    let mut out = Vec::with_capacity(offset);
    out.extend_from_slice(&MAGIC);
    put_u16(&mut out, VERSION);
    put_u16(&mut out, 0); // flags
    put_u32(&mut out, n_sections as u32);
    for &(kind, crc, off, len) in &table {
        put_u32(&mut out, kind);
        put_u32(&mut out, crc);
        put_u64(&mut out, off);
        put_u64(&mut out, len);
    }
    for (i, sec) in std::iter::once(&manifest_section)
        .chain(layer_sections.iter())
        .enumerate()
    {
        while (out.len() as u64) < table[i].2 {
            out.push(0);
        }
        out.extend_from_slice(sec);
    }
    wire::pad_to(&mut out, 8);
    (out, manifest)
}

/// On-disk footprint of the entropy tier, measured while decoding a
/// coded pack (`None` on packs written raw). `layer_array_bytes` aligns
/// with the manifest's layer order: coded layers report the bytes their
/// array streams actually occupy on disk (Huffman-coded plus raw
/// fallback); layers stored raw inside a coded pack report their plain
/// `array_bytes`. This is the measured side of the paper's `N·H` claim —
/// `repro inspect` prints it next to the analytic entropy bound.
#[derive(Clone, Debug, Default)]
pub struct CodedReport {
    /// Per-layer on-disk array-stream bytes, manifest order.
    pub layer_array_bytes: Vec<u64>,
    /// Bytes of the shared (deduplicated) codebooks section.
    pub codebook_bytes: u64,
    /// Huffman-coded streams across all layers.
    pub coded_streams: usize,
}

impl CodedReport {
    /// Total on-disk array bytes across layers (excluding code books).
    pub fn total_array_bytes(&self) -> u64 {
        self.layer_array_bytes.iter().sum()
    }

    /// Total on-disk bytes attributable to the arrays: streams plus the
    /// shared code books that decode them.
    pub fn total_on_disk_bytes(&self) -> u64 {
        self.total_array_bytes() + self.codebook_bytes
    }
}

/// An in-memory `.cerpack`: manifest + layers.
///
/// Note: on a freshly built (not yet written) pack, the manifest's
/// `array_bytes`/`payload_bytes` are 0 placeholders — they are measured
/// during serialization; [`Pack::write_to`] and [`Pack::to_bytes`] return
/// the manifest with measured values filled in, and [`Pack::read`] yields
/// the stored ones.
#[derive(Clone, Debug)]
pub struct Pack {
    pub manifest: Manifest,
    pub layers: Vec<PackLayer>,
    /// Entropy-tier accounting when this pack was decoded from coded
    /// sections; `None` for raw packs and freshly built ones.
    pub coded: Option<CodedReport>,
}

impl Pack {
    /// Build a pack from encoded layers, measuring provenance statistics
    /// (entropy, p₀, K, analytic bits) from each matrix. `rationale` is
    /// recorded verbatim on every layer (e.g. `argmin energy (modeled)`).
    pub fn from_layers(
        network: &str,
        rationale: &str,
        layers: Vec<(String, AnyMatrix, Vec<f32>)>,
    ) -> Pack {
        let pack_layers: Vec<PackLayer> = layers
            .into_iter()
            .map(|(name, matrix, bias)| PackLayer {
                name,
                matrix,
                bias: bias.into(),
            })
            .collect();
        let views: Vec<LayerView<'_>> = pack_layers.iter().map(PackLayer::view).collect();
        let manifest = build_manifest(network, rationale, &views);
        Pack {
            manifest,
            layers: pack_layers,
            coded: None,
        }
    }

    /// Serialize to bytes. Returns the file image together with the
    /// manifest as written (measured byte counts filled in).
    pub fn to_bytes(&self) -> (Vec<u8>, Manifest) {
        let views: Vec<LayerView<'_>> = self.layers.iter().map(PackLayer::view).collect();
        serialize(&self.manifest, &views)
    }

    /// Write to `path`. Returns (file bytes written, manifest as written).
    pub fn write_to(&self, path: &Path) -> Result<(u64, Manifest), PackError> {
        let (bytes, manifest) = self.to_bytes();
        fs::write(path, &bytes)?;
        Ok((bytes.len() as u64, manifest))
    }

    /// Read and fully decode a `.cerpack` file (checksums verified).
    pub fn read(path: &Path) -> Result<Pack, PackError> {
        Self::from_bytes(&fs::read(path)?)
    }

    /// Decode a `.cerpack` from memory (checksums verified). Every array
    /// is decoded into owned storage — the historical copying reader.
    pub fn from_bytes(buf: &[u8]) -> Result<Pack, PackError> {
        let sections = parse_container(buf)?;
        assemble_pack(sections, None)
    }

    /// Decode a `.cerpack` from a shared [`PackMap`] (checksums verified
    /// once, over the mapped bytes). Bulk arrays — values, codebooks,
    /// column indices, biases, and every pointer array whose accounted
    /// width is 32-bit — come back as zero-copy views into `map`; each
    /// view holds an `Arc` clone, so the mapping outlives the pack and
    /// can back any number of engines at once. Entropy-coded layers are
    /// the exception: their arrays are Huffman-decoded into owned
    /// storage (the mapping stays coded on disk).
    pub fn from_map(map: &Arc<PackMap>) -> Result<Pack, PackError> {
        let sections = parse_container(map.bytes())?;
        assemble_pack(sections, Some(map))
    }

    /// Open `path` through the shared storage layer (`mmap(2)` where
    /// available, aligned heap read otherwise) and decode it zero-copy.
    /// Returns the map alongside the pack so callers can share it with
    /// further engines ([`crate::coordinator::PackOptions::from_map`]).
    pub fn open_mapped(path: &Path) -> Result<(Arc<PackMap>, Pack), PackError> {
        let map = PackMap::open(path)?;
        let pack = Pack::from_map(&map)?;
        Ok((map, pack))
    }
}

/// Decode and cross-validate the layer sections against the manifest.
/// With `map`, raw-tier arrays are loaded as zero-copy views; without,
/// as owned copies — identical validation either way. Coded-tier layers
/// always decode into owned storage.
fn assemble_pack(sections: Sections<'_>, map: Option<&Arc<PackMap>>) -> Result<Pack, PackError> {
    let Sections {
        manifest,
        layers: layer_slices,
        codebooks,
    } = sections;
    if layer_slices.len() != manifest.layers.len() {
        return Err(PackError::malformed(format!(
            "{} layer sections but manifest lists {} layers",
            layer_slices.len(),
            manifest.layers.len()
        )));
    }
    let books: Vec<entropy::Decoder> = match codebooks {
        Some(sec) => entropy::decode_codebooks(sec)?,
        None => Vec::new(),
    };
    let any_coded = codebooks.is_some() || layer_slices.iter().any(|s| s.coded);
    let mut report = CodedReport {
        codebook_bytes: codebooks.map_or(0, |s| s.len() as u64),
        ..CodedReport::default()
    };
    let mut layers: Vec<PackLayer> = Vec::with_capacity(layer_slices.len());
    for (i, slice) in layer_slices.iter().enumerate() {
        let layer = if slice.coded {
            let (layer, array_disk_bytes, coded_streams) =
                decode_coded_layer_section(slice.bytes, &books)
                    .map_err(|e| annotate_layer(e, i))?;
            report.layer_array_bytes.push(array_disk_bytes);
            report.coded_streams += coded_streams;
            layer
        } else {
            let src = match map {
                Some(m) => ArrayLoader::mapped(m, slice.off),
                None => ArrayLoader::owned(),
            };
            report
                .layer_array_bytes
                .push(manifest.layers[i].array_bytes);
            decode_layer_section(slice.bytes, src).map_err(|e| annotate_layer(e, i))?
        };
        validate_layer(i, &layer, &manifest.layers[i], layers.last().map(|p| p.matrix.rows()))?;
        layers.push(layer);
    }
    Ok(Pack {
        manifest,
        layers,
        coded: any_coded.then_some(report),
    })
}

/// Cross-validate one decoded layer against its manifest record and the
/// previous layer's output dimension — shared by both the whole-pack
/// readers and the streaming [`stream::PackReader`], so a checksum-valid
/// but inconsistent file errors at load instead of panicking inside
/// `forward()`.
pub(crate) fn validate_layer(
    i: usize,
    layer: &PackLayer,
    prov: &LayerProvenance,
    prev_rows: Option<usize>,
) -> Result<(), PackError> {
    if layer.matrix.rows() != prov.rows as usize
        || layer.matrix.cols() != prov.cols as usize
        || layer.matrix.kind() != prov.format
    {
        return Err(PackError::malformed(format!(
            "layer {i}: payload shape/format disagrees with manifest"
        )));
    }
    if layer.bias.len() != layer.matrix.rows() {
        return Err(PackError::malformed(format!(
            "layer {i}: bias length {} does not match {} rows",
            layer.bias.len(),
            layer.matrix.rows()
        )));
    }
    if let Some(prev) = prev_rows {
        if layer.matrix.cols() != prev {
            return Err(PackError::malformed(format!(
                "layer {i}: input dim {} does not chain with previous output dim {prev}",
                layer.matrix.cols(),
            )));
        }
    }
    Ok(())
}

/// (K, p₀, entropy H) of a matrix's element distribution, computed from
/// the encoded representation — the save path would otherwise materialize
/// a dense copy of every layer (hundreds of MB for paper-scale FC layers)
/// just to fill three manifest fields. Agrees with
/// `DistStats::measure(&matrix.to_dense())` on those fields because the
/// formats are lossless.
fn element_stats(matrix: &AnyMatrix) -> (usize, f64, f64) {
    use crate::formats::codebook::value_key;
    use std::collections::HashMap;

    let n = matrix.rows() as u64 * matrix.cols() as u64;
    if n == 0 {
        return (0, 0.0, 0.0);
    }
    let mut counts: HashMap<u32, u64> = HashMap::new();
    match matrix {
        AnyMatrix::Dense(m) => {
            for &v in m.data() {
                *counts.entry(value_key(v)).or_insert(0) += 1;
            }
        }
        AnyMatrix::Csr(m) => {
            let nnz = m.nnz() as u64;
            if n > nnz {
                *counts.entry(value_key(0.0)).or_insert(0) += n - nnz;
            }
            for &v in m.values.iter() {
                *counts.entry(value_key(v)).or_insert(0) += 1;
            }
        }
        AnyMatrix::Cer(m) => {
            let nnz = m.nnz() as u64;
            if n > nnz {
                *counts.entry(value_key(m.omega[0])).or_insert(0) += n - nnz;
            }
            for r in 0..m.rows() {
                let (s, e) = m.row_runs(r);
                for (j, slot) in (s..e).enumerate() {
                    let run = (m.omega_ptr[slot + 1] - m.omega_ptr[slot]) as u64;
                    if run > 0 {
                        *counts.entry(value_key(m.omega[1 + j])).or_insert(0) += run;
                    }
                }
            }
        }
        AnyMatrix::Cser(m) => {
            let nnz = m.nnz() as u64;
            if n > nnz {
                *counts.entry(value_key(m.omega[0])).or_insert(0) += n - nnz;
            }
            for (slot, &oi) in m.omega_idx.iter().enumerate() {
                let run = (m.omega_ptr[slot + 1] - m.omega_ptr[slot]) as u64;
                if run > 0 {
                    *counts.entry(value_key(m.omega[oi as usize])).or_insert(0) += run;
                }
            }
        }
        AnyMatrix::Bsr(m) => {
            // Count every in-bounds tile cell (stored zeros included —
            // they are real elements of the matrix); everything outside
            // the stored tiles is exactly 0.0. Zero-padded edge cells
            // beyond the matrix bounds are storage, not elements.
            let (br_h, bc_w) = m.block_shape();
            let tile = br_h * bc_w;
            let ncols = m.cols();
            let mut covered = 0u64;
            for br in 0..m.block_rows() {
                let (s, e) = m.block_range(br);
                let rl = br_h.min(m.rows() - br * br_h);
                for idx in s..e {
                    let c0 = m.block_col.get(idx) * bc_w;
                    let cw = bc_w.min(ncols - c0);
                    covered += (rl * cw) as u64;
                    for lr in 0..rl {
                        let base = idx * tile + lr * bc_w;
                        for &v in &m.values[base..base + cw] {
                            *counts.entry(value_key(v)).or_insert(0) += 1;
                        }
                    }
                }
            }
            if n > covered {
                *counts.entry(value_key(0.0)).or_insert(0) += n - covered;
            }
        }
        AnyMatrix::Tnn(m) => {
            let nnz = m.nnz() as u64;
            if n > nnz {
                *counts.entry(value_key(0.0)).or_insert(0) += n - nnz;
            }
            for r in 0..m.rows() {
                let (ss, se) = m.row_slots(r);
                for s in ss..se {
                    let (cs, ce) = (m.seg_ptr[s] as u64, m.seg_ptr[s + 1] as u64);
                    if cs == ce {
                        continue;
                    }
                    let pos = m.split[s] as u64;
                    let mag = m.mags[s - ss];
                    if pos > 0 {
                        *counts.entry(value_key(mag)).or_insert(0) += pos;
                    }
                    let neg = (ce - cs) - pos;
                    if neg > 0 {
                        *counts.entry(value_key(-mag)).or_insert(0) += neg;
                    }
                }
            }
        }
    }
    let total = n as f64;
    let pmf: Vec<f64> = counts.values().map(|&c| c as f64 / total).collect();
    let p0 = counts.values().copied().max().unwrap_or(0) as f64 / total;
    (counts.len(), p0, crate::stats::entropy::entropy_bits(&pmf))
}

fn annotate_layer(e: PackError, i: usize) -> PackError {
    match e {
        PackError::Malformed(m) => PackError::Malformed(format!("layer {i}: {m}")),
        other => other,
    }
}

/// One layer section located inside a pack image: its absolute byte
/// offset (for zero-copy views), its bytes, and which storage tier it
/// was written under.
pub(crate) struct LayerSlice<'a> {
    pub off: usize,
    pub bytes: &'a [u8],
    pub coded: bool,
}

/// Everything [`parse_container`] extracts from a validated pack image.
pub(crate) struct Sections<'a> {
    pub manifest: Manifest,
    /// Layer sections in table order (raw and coded tiers interleaved).
    pub layers: Vec<LayerSlice<'a>>,
    /// The shared code-books section, present iff any layer is coded.
    pub codebooks: Option<&'a [u8]>,
}

/// Validate header + section table + CRCs; return the parsed manifest,
/// the raw/coded layer sections in table order, and the optional shared
/// code-books section. Section offsets must be 8-byte aligned (the
/// writer always aligns them; the zero-copy reader depends on it for
/// every array's natural alignment, so a misaligned offset is rejected
/// as corruption by both readers).
pub(crate) fn parse_container(buf: &[u8]) -> Result<Sections<'_>, PackError> {
    if buf.len() < HEADER_BYTES {
        return if buf.len() >= 8 && buf[..8] != MAGIC {
            Err(PackError::BadMagic)
        } else {
            Err(PackError::Truncated)
        };
    }
    if buf[..8] != MAGIC {
        return Err(PackError::BadMagic);
    }
    let mut cur = Cursor::new(&buf[8..HEADER_BYTES]);
    let version = cur.u16()?;
    let flags = cur.u16()?;
    let n_sections = cur.u32()?;
    if version != VERSION {
        return Err(PackError::UnsupportedVersion(version));
    }
    // Reserved: a future writer setting an unknown flag (e.g. a new
    // coding tier) must be rejected cleanly, like an unknown version.
    if flags & !FLAG_ENTROPY != 0 {
        return Err(PackError::malformed(format!("unsupported flags 0x{flags:04x}")));
    }
    let entropy_flagged = flags & FLAG_ENTROPY != 0;
    if n_sections == 0 || n_sections > MAX_SECTIONS {
        return Err(PackError::malformed(format!(
            "implausible section count {n_sections}"
        )));
    }
    let table_end = HEADER_BYTES + n_sections as usize * TABLE_ENTRY_BYTES;
    if buf.len() < table_end {
        return Err(PackError::Truncated);
    }
    let mut cur = Cursor::new(&buf[HEADER_BYTES..table_end]);
    let mut manifest: Option<Manifest> = None;
    let mut layer_slices: Vec<LayerSlice<'_>> = Vec::new();
    let mut codebooks: Option<&[u8]> = None;
    let mut max_end = table_end as u64;
    for i in 0..n_sections as usize {
        let kind = cur.u32()?;
        let crc = cur.u32()?;
        let off = cur.u64()?;
        let len = cur.u64()?;
        if off % 8 != 0 {
            return Err(PackError::malformed(format!(
                "section {i} offset {off} is not 8-byte aligned"
            )));
        }
        let end = off.checked_add(len).ok_or(PackError::Truncated)?;
        if end > buf.len() as u64 {
            return Err(PackError::Truncated);
        }
        max_end = max_end.max(end);
        let sec = &buf[off as usize..end as usize];
        if crc32(sec) != crc {
            return Err(PackError::ChecksumMismatch { section: i });
        }
        match kind {
            SECTION_MANIFEST => {
                if manifest.is_some() {
                    return Err(PackError::malformed("duplicate manifest section"));
                }
                if i != 0 {
                    return Err(PackError::malformed("manifest is not the first section"));
                }
                manifest = Some(decode_manifest(sec)?);
            }
            SECTION_LAYER => layer_slices.push(LayerSlice {
                off: off as usize,
                bytes: sec,
                coded: false,
            }),
            SECTION_LAYER_CODED => {
                if !entropy_flagged {
                    return Err(PackError::malformed(
                        "coded layer section in a pack without the entropy flag",
                    ));
                }
                layer_slices.push(LayerSlice {
                    off: off as usize,
                    bytes: sec,
                    coded: true,
                });
            }
            SECTION_CODEBOOKS => {
                if !entropy_flagged {
                    return Err(PackError::malformed(
                        "code-books section in a pack without the entropy flag",
                    ));
                }
                if codebooks.is_some() {
                    return Err(PackError::malformed("duplicate code-books section"));
                }
                codebooks = Some(sec);
            }
            other => {
                return Err(PackError::malformed(format!(
                    "unknown section kind {other}"
                )))
            }
        }
    }
    let manifest = manifest.ok_or_else(|| PackError::malformed("missing manifest section"))?;
    // The file must be exactly the sections plus their trailing 8-byte
    // alignment padding: a cut anywhere — even inside the final pad — is
    // truncation, and extra bytes are not silently carried along.
    let expected_len = (max_end + 7) & !7;
    if (buf.len() as u64) < expected_len {
        return Err(PackError::Truncated);
    }
    if buf.len() as u64 > expected_len {
        return Err(PackError::malformed("trailing bytes after the last section"));
    }
    Ok(Sections {
        manifest,
        layers: layer_slices,
        codebooks,
    })
}

fn encode_manifest(m: &Manifest) -> Vec<u8> {
    let mut out = Vec::new();
    put_string(&mut out, &m.network);
    put_string(&mut out, &m.created_by);
    put_u32(&mut out, m.layers.len() as u32);
    for l in &m.layers {
        put_string(&mut out, &l.name);
        out.push(l.format.tag());
        put_u32(&mut out, l.rows);
        put_u32(&mut out, l.cols);
        put_u32(&mut out, l.k);
        put_f64(&mut out, l.entropy);
        put_f64(&mut out, l.p0);
        put_u64(&mut out, l.analytic_bits);
        put_u64(&mut out, l.array_bytes);
        put_u64(&mut out, l.payload_bytes);
        put_string(&mut out, &l.rationale);
    }
    out
}

fn decode_manifest(buf: &[u8]) -> Result<Manifest, PackError> {
    let mut cur = Cursor::new(buf);
    let network = cur.string()?;
    let created_by = cur.string()?;
    let n = cur.u32_len("manifest layer count")?;
    if n > MAX_SECTIONS as usize {
        return Err(PackError::malformed("implausible manifest layer count"));
    }
    let mut layers = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        let name = cur.string()?;
        let tag = cur.u8()?;
        let format = FormatKind::from_tag(tag)
            .ok_or_else(|| PackError::malformed(format!("unknown format tag {tag}")))?;
        layers.push(LayerProvenance {
            name,
            format,
            rows: cur.u32()?,
            cols: cur.u32()?,
            k: cur.u32()?,
            entropy: cur.f64()?,
            p0: cur.f64()?,
            analytic_bits: cur.u64()?,
            array_bytes: cur.u64()?,
            payload_bytes: cur.u64()?,
            rationale: cur.string()?,
        });
    }
    if cur.remaining() != 0 {
        return Err(PackError::malformed("trailing bytes after manifest"));
    }
    Ok(Manifest {
        network,
        created_by,
        layers,
    })
}

fn decode_layer_section(buf: &[u8], src: ArrayLoader<'_>) -> Result<PackLayer, PackError> {
    let mut cur = Cursor::new(buf);
    let name = cur.string()?;
    cur.align(4)?;
    let bias_len = cur.u32_len("bias length")?;
    let bias = src.typed::<f32>(&mut cur, bias_len, "bias")?;
    let payload_len = cur.u64_len("payload length")?;
    let payload_pos = cur.pos();
    let payload = cur.take(payload_len)?;
    if cur.remaining() != 0 {
        return Err(PackError::malformed("trailing bytes after layer payload"));
    }
    let matrix = AnyMatrix::decode_from_source(payload, src.advanced(payload_pos))?;
    Ok(PackLayer { name, matrix, bias })
}

/// Decode a coded-tier layer section: validate the tier word, read the
/// header fields, Huffman-decode the stream list back into the exact raw
/// payload bytes, then hand that payload to the ordinary owned decoder —
/// bit-identity with the raw tier holds by construction. Returns the
/// layer plus (on-disk array-stream bytes, Huffman stream count).
pub(crate) fn decode_coded_layer_section(
    buf: &[u8],
    books: &[entropy::Decoder],
) -> Result<(PackLayer, u64, usize), PackError> {
    let mut cur = Cursor::new(buf);
    let tier = cur.u32()?;
    if tier & !TIER_HUFFMAN != 0 {
        return Err(PackError::malformed(format!(
            "unknown tier flags 0x{tier:08x}"
        )));
    }
    if tier != TIER_HUFFMAN {
        return Err(PackError::malformed(
            "coded layer section with no coding tier set",
        ));
    }
    let name = cur.string()?;
    cur.align(4)?;
    let bias_len = cur.u32_len("bias length")?;
    let bias = ArrayLoader::owned().typed::<f32>(&mut cur, bias_len, "bias")?;
    let payload_len = cur.u64_len("payload length")?;
    let dec = entropy::decode_streams(&mut cur, books, payload_len)?;
    if cur.remaining() != 0 {
        return Err(PackError::malformed("trailing bytes after coded streams"));
    }
    if dec.payload.len() != payload_len {
        return Err(PackError::malformed(format!(
            "coded streams reconstruct {} bytes but the section declares {payload_len}",
            dec.payload.len()
        )));
    }
    let matrix = AnyMatrix::decode_from(&dec.payload)?;
    Ok((
        PackLayer { name, matrix, bias },
        dec.array_disk_bytes,
        dec.coded_streams,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Dense;
    use crate::paper_example_matrix;

    fn tiny_pack() -> Pack {
        let m = paper_example_matrix();
        Pack::from_layers(
            "unit-test-net",
            "fixed (test)",
            vec![
                (
                    "fc0".to_string(),
                    AnyMatrix::encode(FormatKind::Cser, &m),
                    vec![0.5; 5],
                ),
                (
                    "fc1".to_string(),
                    AnyMatrix::encode(FormatKind::Dense, &Dense::zeros(3, 5)),
                    vec![-0.25, 0.0, 0.25],
                ),
            ],
        )
    }

    #[test]
    fn roundtrip_in_memory() {
        let pack = tiny_pack();
        let (bytes, written) = pack.to_bytes();
        assert!(written.layers.iter().all(|l| l.payload_bytes > 0));
        let back = Pack::from_bytes(&bytes).expect("decode");
        assert_eq!(back.manifest.network, "unit-test-net");
        assert_eq!(back.layers.len(), 2);
        assert_eq!(back.layers[0].name, "fc0");
        assert_eq!(back.layers[0].bias, vec![0.5; 5]);
        assert_eq!(back.layers[0].matrix.to_dense(), paper_example_matrix());
        assert_eq!(back.layers[0].matrix.kind(), FormatKind::Cser);
        assert_eq!(back.layers[1].matrix.kind(), FormatKind::Dense);
        // Deterministic: re-serialization is byte-identical.
        let (bytes2, _) = back.to_bytes();
        assert_eq!(bytes, bytes2);
    }

    #[test]
    fn measured_array_bytes_match_analytic_bits() {
        let pack = tiny_pack();
        let (_, manifest) = pack.to_bytes();
        for l in &manifest.layers {
            assert_eq!(
                l.array_bytes * 8,
                l.analytic_bits,
                "{}: disk arrays must match the storage model",
                l.name
            );
            assert!(l.divergence_pct().abs() < 1e-9);
        }
    }

    #[test]
    fn element_stats_match_dense_measurement() {
        // element_stats re-derives (K, p0, H) from the encoded arrays; it
        // must agree with the dense-side DistStats on every format.
        let mut rng = crate::util::Rng::new(0x57A7);
        let values = [0.0f32, 0.5, -0.5, 1.0, 2.0];
        let data: Vec<f32> = (0..40 * 17).map(|_| values[rng.below(5)]).collect();
        let m = Dense::from_vec(40, 17, data);
        let want = crate::costmodel::DistStats::measure(&m);
        for kind in FormatKind::ALL {
            let (k, p0, h) = element_stats(&AnyMatrix::encode(kind, &m));
            assert_eq!(k, want.k, "{kind:?}: K");
            assert!((p0 - want.p0).abs() < 1e-12, "{kind:?}: p0 {p0} vs {}", want.p0);
            assert!((h - want.entropy).abs() < 1e-9, "{kind:?}: H {h} vs {}", want.entropy);
        }
    }

    #[test]
    fn bad_magic_detected() {
        let (mut bytes, _) = tiny_pack().to_bytes();
        bytes[0] ^= 0xFF;
        assert!(matches!(Pack::from_bytes(&bytes), Err(PackError::BadMagic)));
    }

    #[test]
    fn unsupported_version_detected() {
        let (mut bytes, _) = tiny_pack().to_bytes();
        bytes[8] = 0xFE;
        let r = Pack::from_bytes(&bytes);
        assert!(matches!(r, Err(PackError::UnsupportedVersion(_))));
    }

    #[test]
    fn flipped_byte_is_a_checksum_error() {
        let (bytes, _) = tiny_pack().to_bytes();
        // Flip one byte in the interior of every section (offsets read
        // from the section table); each must surface as a checksum
        // mismatch. The header/table region is covered by the structural
        // checks instead.
        for i in 0..3usize {
            let entry = HEADER_BYTES + i * TABLE_ENTRY_BYTES;
            let off = u64::from_le_bytes(bytes[entry + 8..entry + 16].try_into().unwrap());
            let len = u64::from_le_bytes(bytes[entry + 16..entry + 24].try_into().unwrap());
            let pos = (off + len / 2) as usize;
            let mut corrupt = bytes.clone();
            corrupt[pos] ^= 0x40;
            assert!(
                matches!(
                    Pack::from_bytes(&corrupt),
                    Err(PackError::ChecksumMismatch { section }) if section == i
                ),
                "flip at {pos} (section {i}) not caught"
            );
        }
    }

    #[test]
    fn truncation_detected_at_every_length() {
        let (bytes, _) = tiny_pack().to_bytes();
        // Every proper prefix must fail cleanly (no panic, no Ok).
        for cut in [0, 4, 8, 15, HEADER_BYTES, HEADER_BYTES + 10, bytes.len() - 1] {
            let r = Pack::from_bytes(&bytes[..cut]);
            assert!(r.is_err(), "prefix of {cut} bytes decoded successfully");
        }
    }

    #[test]
    fn manifest_only_read_skips_payload_decode() {
        let pack = tiny_pack();
        let (bytes, written) = pack.to_bytes();
        let sections = parse_container(&bytes).unwrap();
        let manifest = &sections.manifest;
        assert_eq!(sections.layers.len(), 2);
        assert!(sections.codebooks.is_none());
        assert!(sections.layers.iter().all(|s| !s.coded));
        assert_eq!(manifest.layers[0].payload_bytes, written.layers[0].payload_bytes);
        assert_eq!(manifest.total_analytic_bits(), written.total_analytic_bits());
        assert!(manifest.dense_baseline_bytes() >= manifest.total_array_bytes());
    }

    #[test]
    fn unknown_header_flag_is_rejected() {
        let (mut bytes, _) = tiny_pack().to_bytes();
        // Flags live at bytes 10..12 (after magic + version). Bit 0 is
        // the entropy tier; any other bit must fail like an unknown
        // version — a v-next writer's packs are rejected, not misparsed.
        bytes[10] = 0x02;
        let err = Pack::from_bytes(&bytes).unwrap_err();
        assert!(
            err.to_string().contains("unsupported flags"),
            "got: {err}"
        );
    }

    /// A pack big and skewed enough that Huffman streams pay for
    /// themselves: a quantized 48×31 CSER layer (coded tier) chained
    /// into a small dense layer (floats — stays raw inside the coded
    /// pack).
    fn skewed_pack() -> Pack {
        let mut rng = crate::util::Rng::new(0xBEEF);
        let values = [0.0f32, 0.0, 0.0, 0.5, -0.5, 1.5];
        let data: Vec<f32> = (0..48 * 31).map(|_| values[rng.below(6)]).collect();
        let m = Dense::from_vec(48, 31, data);
        Pack::from_layers(
            "unit-test-coded-net",
            "fixed (test)",
            vec![
                (
                    "fc0".to_string(),
                    AnyMatrix::encode(FormatKind::Cser, &m),
                    vec![0.25; 48],
                ),
                (
                    "fc1".to_string(),
                    AnyMatrix::encode(FormatKind::Dense, &Dense::zeros(3, 48)),
                    vec![0.0; 3],
                ),
            ],
        )
    }

    fn coded_image(pack: &Pack) -> Vec<u8> {
        let opts = stream::EncodeOptions { entropy: true };
        let mut bytes = std::io::Cursor::new(Vec::new());
        stream::write_pack(
            &mut bytes,
            &pack.manifest,
            pack.layers.iter().map(PackLayer::view),
            &opts,
        )
        .unwrap();
        bytes.into_inner()
    }

    #[test]
    fn coded_section_requires_the_entropy_flag() {
        // A coded pack whose header flag is cleared must be rejected:
        // the flag is the forward-compat gate, so readers that predate
        // the entropy tier fail on the flag, and readers that know it
        // insist on consistency.
        let pack = skewed_pack();
        let mut bytes = coded_image(&pack);
        let back = Pack::from_bytes(&bytes).expect("coded pack decodes");
        let report = back.coded.expect("pack must actually be coded");
        assert!(report.coded_streams > 0, "fixture produced no coded streams");
        bytes[10] &= !(FLAG_ENTROPY as u8);
        let err = Pack::from_bytes(&bytes).unwrap_err();
        assert!(
            err.to_string().contains("without the entropy flag"),
            "got: {err}"
        );
    }

    #[test]
    fn unknown_tier_flag_is_rejected() {
        // Build a coded pack, then set a reserved bit in the first coded
        // layer's tier word (repairing the section CRC so the tier check
        // itself is what fires).
        let pack = skewed_pack();
        let mut bytes = coded_image(&pack);
        let n_sections = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
        let coded_at = (0..n_sections)
            .map(|i| HEADER_BYTES + i * TABLE_ENTRY_BYTES)
            .find(|&e| {
                u32::from_le_bytes(bytes[e..e + 4].try_into().unwrap()) == SECTION_LAYER_CODED
            })
            .expect("a coded layer section");
        let off = u64::from_le_bytes(bytes[coded_at + 8..coded_at + 16].try_into().unwrap())
            as usize;
        let len = u64::from_le_bytes(bytes[coded_at + 16..coded_at + 24].try_into().unwrap())
            as usize;
        bytes[off + 1] |= 0x80; // tier word bit 15
        let crc = crc32(&bytes[off..off + len]);
        bytes[coded_at + 4..coded_at + 8].copy_from_slice(&crc.to_le_bytes());
        let err = Pack::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("unknown tier flags"), "got: {err}");
    }

    #[test]
    fn coded_pack_roundtrips_bit_identically() {
        let pack = skewed_pack();
        let bytes = coded_image(&pack);
        let back = Pack::from_bytes(&bytes).expect("decode coded");
        let report = back.coded.as_ref().expect("coded report");
        assert_eq!(report.layer_array_bytes.len(), back.layers.len());
        assert!(report.coded_streams > 0);
        // Coded on-disk array bytes never exceed the raw tier's.
        assert!(report.total_array_bytes() <= back.manifest.total_array_bytes());
        for (a, b) in pack.layers.iter().zip(&back.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.bias, b.bias);
            assert_eq!(a.matrix.kind(), b.matrix.kind());
            assert_eq!(a.matrix.to_dense(), b.matrix.to_dense());
        }
        // The coded image re-serializes raw into the canonical bytes —
        // and the mapped reader agrees with the owned one.
        let (raw_bytes, _) = pack.to_bytes();
        let (back_bytes, _) = back.to_bytes();
        assert_eq!(raw_bytes, back_bytes);
        let map = PackMap::from_bytes(&bytes);
        let mapped = Pack::from_map(&map).expect("decode coded via map");
        let (mapped_bytes, _) = mapped.to_bytes();
        assert_eq!(raw_bytes, mapped_bytes);
    }

    #[test]
    fn entropy_writer_falls_back_to_raw_when_coding_cannot_pay() {
        // Tiny layers: every candidate stream costs more coded than raw,
        // so the writer must emit a plain raw pack — entropy flag clear,
        // no code-books section — that decodes to the same network.
        let pack = tiny_pack();
        let bytes = coded_image(&pack);
        assert_eq!(u16::from_le_bytes(bytes[10..12].try_into().unwrap()), 0);
        let back = Pack::from_bytes(&bytes).unwrap();
        assert!(back.coded.is_none());
        let (raw, _) = pack.to_bytes();
        let (again, _) = back.to_bytes();
        assert_eq!(raw, again);
    }
}
