//! `.cerpack` integration tests: seeded-RNG round-trip properties across
//! the whole format family — every [`FormatKind::ALL`] entry, including
//! the BSR and TNN section codecs — and all index widths (save → load
//! must be bit-exact),
//! the paper-example acceptance check (measured on-disk size vs the
//! analytic `StorageBreakdown`), and corruption handling (truncated file,
//! bad magic, flipped byte → clean typed errors, never UB or garbage
//! weights).

use std::path::PathBuf;

use cer::coordinator::{Engine, PackOptions};
use cer::formats::{Dense, FormatKind};
use cer::kernels::AnyMatrix;
use cer::pack::stream::EncodeOptions;
use cer::pack::{Pack, PackError, SECTION_LAYER_CODED};
use cer::util::{crc32, Rng};

/// A quantized random matrix with ~`k` distinct values and a heavy zero
/// mass (the regime the formats are built for).
fn random_quantized(rng: &mut Rng, rows: usize, cols: usize, k: usize) -> Dense {
    let values: Vec<f32> = (0..k)
        .map(|i| (i as f32 - (k / 2) as f32) * 0.25)
        .collect();
    let data: Vec<f32> = (0..rows * cols)
        .map(|_| {
            if rng.f64() < 0.4 {
                0.0
            } else {
                values[rng.below(k)]
            }
        })
        .collect();
    Dense::from_vec(rows, cols, data)
}

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "cer-pack-test-{}-{tag}.cerpack",
        std::process::id()
    ))
}

#[test]
fn payload_roundtrip_all_formats_across_index_widths() {
    let mut rng = Rng::new(0x9ACC);
    // Shapes chosen to force u8 / u16 / u32 column-index widths and u8 /
    // u16 pointer widths (nnz and run counts above and below 255).
    let shapes: [(usize, usize); 5] = [(7, 40), (3, 300), (2, 70_000), (60, 200), (200, 90)];
    for &(rows, cols) in &shapes {
        for k in [1usize, 2, 5, 17] {
            let m = random_quantized(&mut rng, rows, cols, k);
            for kind in FormatKind::ALL {
                let enc = AnyMatrix::encode(kind, &m);
                let mut buf = Vec::new();
                let emitted = enc.encode_into(&mut buf);
                assert_eq!(emitted.total, buf.len(), "{kind:?} {rows}x{cols}");
                // The matrix arrays on disk must match the paper's
                // analytic storage accounting bit for bit.
                assert_eq!(
                    emitted.arrays as u64 * 8,
                    enc.storage().total_bits(),
                    "{kind:?} {rows}x{cols} k={k}: disk arrays vs storage model"
                );
                let dec = AnyMatrix::decode_from(&buf)
                    .unwrap_or_else(|e| panic!("{kind:?} {rows}x{cols}: {e}"));
                assert_eq!(dec.kind(), kind);
                // Lossless and bit-exact.
                assert_eq!(dec.to_dense(), m, "{kind:?} {rows}x{cols} k={k}");
                // Deterministic: re-encoding reproduces the exact bytes.
                let mut buf2 = Vec::new();
                dec.encode_into(&mut buf2);
                assert_eq!(buf, buf2, "{kind:?} {rows}x{cols} k={k}");
            }
        }
    }
}

#[test]
fn paper_example_on_disk_size_matches_storage_breakdown() {
    // Acceptance: the measured `.cerpack` bytes for the paper's 5x12
    // example must be within 10% of the `StorageBreakdown` prediction.
    // The array bytes match it *exactly* (the codecs store pointer/index
    // arrays at the same minimal widths the accounting uses).
    let m = cer::paper_example_matrix();
    for kind in FormatKind::ALL {
        let enc = AnyMatrix::encode(kind, &m);
        let mut buf = Vec::new();
        let emitted = enc.encode_into(&mut buf);
        let analytic_bits = enc.storage().total_bits();
        assert_eq!(
            emitted.arrays as u64 * 8,
            analytic_bits,
            "{kind:?}: measured arrays diverge from the analytic bound"
        );
        let div = (emitted.arrays as f64 * 8.0 / analytic_bits as f64 - 1.0).abs();
        assert!(div < 0.10, "{kind:?}: divergence {div}");
    }
    // CSER analytic storage of the example is 568 bits (§III-A: 59
    // entries = 4x32 + 28x8 + 10x8 + 11x8 + 6x8) — 71 bytes on disk.
    let cser = AnyMatrix::encode(FormatKind::Cser, &m);
    let mut buf = Vec::new();
    assert_eq!(cser.encode_into(&mut buf).arrays, 71);
}

#[test]
fn engine_save_load_bit_exact_for_every_format() {
    let mut rng = Rng::new(0xE2E);
    for kind in FormatKind::ALL {
        let layers: Vec<(String, Dense, Vec<f32>)> = vec![
            (
                "fc0".into(),
                random_quantized(&mut rng, 9, 14, 6),
                (0..9).map(|i| i as f32 * 0.1).collect(),
            ),
            (
                "fc1".into(),
                random_quantized(&mut rng, 4, 9, 3),
                vec![0.0; 4],
            ),
        ];
        let mut original = Engine::native_fixed(layers, kind);
        let path = tmp_path(&format!("fixed-{}", kind.name()));
        original.save_pack(&path, "roundtrip-net", "fixed (test)").unwrap();
        let mut cold = PackOptions::new(&path).open().unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(cold.formats(), vec![kind; 2]);
        let x: Vec<f32> = (0..2 * 14).map(|_| rng.f32() - 0.5).collect();
        let a = original.forward(&x, 2).unwrap();
        let b = cold.forward(&x, 2).unwrap();
        assert_eq!(a, b, "{kind:?}: cold-start forward must be bit-exact");
    }
}

/// Build a small real pack file and return its bytes.
fn sample_pack_bytes() -> Vec<u8> {
    let mut rng = Rng::new(0xC0DE);
    let pack = Pack::from_layers(
        "corruption-net",
        "fixed (test)",
        vec![
            (
                "a".to_string(),
                AnyMatrix::encode(FormatKind::Cser, &random_quantized(&mut rng, 12, 30, 7)),
                vec![0.0; 12],
            ),
            (
                "b".to_string(),
                AnyMatrix::encode(FormatKind::Csr, &random_quantized(&mut rng, 5, 12, 4)),
                vec![0.1; 5],
            ),
        ],
    );
    pack.to_bytes().0
}

#[test]
fn truncated_file_fails_cleanly() {
    let bytes = sample_pack_bytes();
    let path = tmp_path("trunc");
    // Every prefix (sampled densely) must produce an error — and in
    // particular never panic or return a mangled pack.
    let mut cuts: Vec<usize> = (0..bytes.len()).step_by(7).collect();
    cuts.extend([0, 1, 8, 15, 16, bytes.len() - 1]);
    for cut in cuts {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let r = Pack::read(&path);
        assert!(r.is_err(), "prefix of {cut} bytes decoded successfully");
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn bad_magic_fails_with_typed_error() {
    let mut bytes = sample_pack_bytes();
    bytes[..8].copy_from_slice(b"NOTAPACK");
    let path = tmp_path("magic");
    std::fs::write(&path, &bytes).unwrap();
    assert!(matches!(Pack::read(&path), Err(PackError::BadMagic)));
    std::fs::remove_file(&path).ok();

    // An engine cold start surfaces the same failure as a readable error.
    let path2 = tmp_path("magic2");
    std::fs::write(&path2, &bytes).unwrap();
    let e = PackOptions::new(&path2).open().unwrap_err();
    assert!(format!("{e:#}").contains("bad magic"), "{e:#}");
    std::fs::remove_file(&path2).ok();
}

#[test]
fn every_flipped_section_byte_is_a_checksum_error() {
    let bytes = sample_pack_bytes();
    // Parse the section table (header: magic 8, version 2, flags 2,
    // count 4; entries of 24 bytes: kind u32, crc u32, off u64, len u64).
    let n_sections = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    assert_eq!(n_sections, 3); // manifest + 2 layers
    let path = tmp_path("flip");
    for s in 0..n_sections {
        let entry = 16 + s * 24;
        let off = u64::from_le_bytes(bytes[entry + 8..entry + 16].try_into().unwrap()) as usize;
        let len = u64::from_le_bytes(bytes[entry + 16..entry + 24].try_into().unwrap()) as usize;
        // Flip a byte at several positions inside the section.
        for pos in [off, off + len / 3, off + len / 2, off + len - 1] {
            let mut corrupt = bytes.clone();
            corrupt[pos] ^= 0x01;
            std::fs::write(&path, &corrupt).unwrap();
            match Pack::read(&path) {
                Err(PackError::ChecksumMismatch { section }) => assert_eq!(section, s),
                other => panic!("flip at {pos}: expected checksum error, got {other:?}"),
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn header_and_table_corruption_fails_cleanly() {
    let bytes = sample_pack_bytes();
    let path = tmp_path("table");
    // Version byte, section count, and every table byte: flipping any of
    // them must yield an error (checksum, truncated, malformed, or
    // version), never an Ok pack or a panic.
    let mut positions: Vec<usize> = vec![8, 9, 12, 13, 14, 15];
    positions.extend(16..16 + 3 * 24);
    for pos in positions {
        let mut corrupt = bytes.clone();
        corrupt[pos] ^= 0x80;
        std::fs::write(&path, &corrupt).unwrap();
        assert!(Pack::read(&path).is_err(), "flip at header/table byte {pos}");
    }
    std::fs::remove_file(&path).ok();
}

/// A matrix whose value mass is skewed enough that the Huffman tier pays
/// for itself (codebook-index streams compress well below their raw
/// minimal width once the arrays are a few thousand entries long).
fn skewed_quantized(rng: &mut Rng, rows: usize, cols: usize) -> Dense {
    let values = [0.0f32, 0.0, 0.0, 0.0, 0.5, -0.5, 1.5];
    Dense::from_vec(
        rows,
        cols,
        (0..rows * cols)
            .map(|_| values[rng.below(values.len())])
            .collect(),
    )
}

/// Save an entropy-coded two-layer pack and return its raw file bytes.
fn coded_pack_bytes(tag: &str) -> Vec<u8> {
    let mut rng = Rng::new(0xC0DE);
    let layers = vec![
        (
            "fc0".to_string(),
            skewed_quantized(&mut rng, 64, 96),
            vec![0.0; 64],
        ),
        (
            "fc1".to_string(),
            skewed_quantized(&mut rng, 10, 64),
            vec![0.5; 10],
        ),
    ];
    let engine = Engine::native_fixed(layers, FormatKind::Cser);
    let path = tmp_path(tag);
    let summary = engine
        .save_pack_with(&path, "coded-net", "fixed (test)", &EncodeOptions { entropy: true })
        .unwrap();
    let report = summary.coded.expect("fixture must produce a coded pack");
    assert!(report.coded_streams > 0, "fixture produced no coded streams");
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    bytes
}

/// `(kind, crc_field_pos, section_off, section_len)` per table entry.
fn section_table(bytes: &[u8]) -> Vec<(u32, usize, usize, usize)> {
    let n = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    (0..n)
        .map(|s| {
            let e = 16 + s * 24;
            let kind = u32::from_le_bytes(bytes[e..e + 4].try_into().unwrap());
            let off = u64::from_le_bytes(bytes[e + 8..e + 16].try_into().unwrap()) as usize;
            let len = u64::from_le_bytes(bytes[e + 16..e + 24].try_into().unwrap()) as usize;
            (kind, e + 4, off, len)
        })
        .collect()
}

/// Recompute a section's CRC after tampering with its bytes, so decoding
/// exercises the entropy decoder itself instead of stopping at the
/// checksum.
fn repair_crc(bytes: &mut [u8], crc_pos: usize, off: usize, len: usize) {
    let crc = crc32(&bytes[off..off + len]);
    bytes[crc_pos..crc_pos + 4].copy_from_slice(&crc.to_le_bytes());
}

#[test]
fn coded_pack_cold_start_is_bit_exact_owned_and_mapped() {
    let mut rng = Rng::new(0xC0DE);
    let layers = vec![
        (
            "fc0".to_string(),
            skewed_quantized(&mut rng, 64, 96),
            vec![0.0; 64],
        ),
        (
            "fc1".to_string(),
            skewed_quantized(&mut rng, 10, 64),
            vec![0.5; 10],
        ),
    ];
    let mut original = Engine::native_fixed(layers, FormatKind::Cser);
    let path = tmp_path("coded-exact");
    let summary = original
        .save_pack_with(&path, "coded-net", "fixed (test)", &EncodeOptions { entropy: true })
        .unwrap();
    let report = summary.coded.expect("coded pack expected");
    assert!(report.coded_streams > 0);
    // The tier's whole point: coded on-disk arrays (code books included)
    // never exceed the raw minimal-width arrays.
    assert!(report.total_on_disk_bytes() <= summary.manifest.total_array_bytes());
    let mut owned = PackOptions::new(&path).open().unwrap();
    let mut mapped = PackOptions::new(&path).mmap(true).open().unwrap();
    std::fs::remove_file(&path).ok();
    let x: Vec<f32> = (0..2 * 96).map(|_| rng.f32() - 0.5).collect();
    let a = original.forward(&x, 2).unwrap();
    assert_eq!(a, owned.forward(&x, 2).unwrap(), "owned coded cold start");
    assert_eq!(a, mapped.forward(&x, 2).unwrap(), "mapped coded cold start");
}

#[test]
fn flipped_coded_section_bytes_fail_the_checksum() {
    let bytes = coded_pack_bytes("coded-flip");
    let coded: Vec<_> = section_table(&bytes)
        .into_iter()
        .filter(|(k, ..)| *k == SECTION_LAYER_CODED)
        .collect();
    assert!(!coded.is_empty(), "fixture has no coded sections");
    for (_, _, off, len) in coded {
        for pos in [off, off + len / 2, off + len - 1] {
            let mut corrupt = bytes.clone();
            corrupt[pos] ^= 0x10;
            assert!(
                matches!(
                    Pack::from_bytes(&corrupt),
                    Err(PackError::ChecksumMismatch { .. })
                ),
                "flip at {pos} must fail the coded section's CRC"
            );
        }
    }
}

#[test]
fn corrupt_tier_word_with_repaired_crc_is_rejected() {
    let bytes = coded_pack_bytes("coded-tier");
    let (_, crc_pos, off, len) = section_table(&bytes)
        .into_iter()
        .find(|(k, ..)| *k == SECTION_LAYER_CODED)
        .expect("coded section");
    // An unknown tier bit (a future coding scheme) must be rejected, not
    // skipped — CRC-valid, so this exercises the tier gate itself.
    let mut unknown = bytes.clone();
    unknown[off..off + 4].copy_from_slice(&0x3u32.to_le_bytes());
    repair_crc(&mut unknown, crc_pos, off, len);
    let err = Pack::from_bytes(&unknown).unwrap_err();
    assert!(err.to_string().contains("unknown tier flags"), "got: {err}");
    // A coded section claiming no tier at all is malformed.
    let mut zero = bytes.clone();
    zero[off..off + 4].copy_from_slice(&0u32.to_le_bytes());
    repair_crc(&mut zero, crc_pos, off, len);
    let err = Pack::from_bytes(&zero).unwrap_err();
    assert!(err.to_string().contains("no coding tier"), "got: {err}");
}

#[test]
fn corrupt_coded_payload_with_repaired_crc_never_panics() {
    // Bit flips *behind* a repaired CRC reach the Huffman decoder with a
    // plausible-looking stream. A flip may still decode (it can land in
    // a name byte or a raw run), so `Err` is not the invariant — the
    // invariant is: no panic, and any `Ok` pack is structurally
    // consistent with its own manifest.
    let bytes = coded_pack_bytes("coded-fuzz");
    for (kind, crc_pos, off, len) in section_table(&bytes) {
        if kind != SECTION_LAYER_CODED {
            continue;
        }
        for pos in (off + 4..off + len).step_by(11) {
            for mask in [0x01u8, 0x80] {
                let mut corrupt = bytes.clone();
                corrupt[pos] ^= mask;
                repair_crc(&mut corrupt, crc_pos, off, len);
                if let Ok(p) = Pack::from_bytes(&corrupt) {
                    assert_eq!(p.layers.len(), p.manifest.layers.len());
                    for (l, m) in p.layers.iter().zip(&p.manifest.layers) {
                        assert_eq!(l.name, m.name);
                    }
                }
            }
        }
    }
}

#[test]
fn pack_preserves_manifest_provenance() {
    let bytes = sample_pack_bytes();
    let pack = Pack::from_bytes(&bytes).unwrap();
    assert_eq!(pack.manifest.network, "corruption-net");
    assert_eq!(pack.manifest.layers.len(), 2);
    let l0 = &pack.manifest.layers[0];
    assert_eq!(l0.format, FormatKind::Cser);
    assert_eq!((l0.rows, l0.cols), (12, 30));
    assert!(l0.entropy > 0.0 && l0.p0 > 0.0 && l0.k >= 2);
    assert_eq!(l0.rationale, "fixed (test)");
    // Stored measured bytes must match a fresh encoding.
    let mut buf = Vec::new();
    let emitted = pack.layers[0].matrix.encode_into(&mut buf);
    assert_eq!(l0.payload_bytes, emitted.total as u64);
    assert_eq!(l0.array_bytes, emitted.arrays as u64);
    assert_eq!(l0.analytic_bits, pack.layers[0].matrix.storage().total_bits());
}
