//! TCP accept loop and lifecycle: bind → accept → thread-per-connection,
//! with graceful drain on SIGTERM or admin request.
//!
//! The listener socket is nonblocking and the accept loop polls a stop
//! flag between attempts, so "stop accepting" takes effect within
//! milliseconds without needing epoll or self-pipes. Shutdown order is
//! the invariant that makes drain graceful:
//!
//! 1. stop accepting (new connections get RST once the socket closes);
//! 2. wait for the admission gauge to reach zero — every in-flight
//!    request has been answered;
//! 3. drop the route table, which flushes worker batchers and joins
//!    worker threads ([`crate::coordinator::server::InferenceServer`]'s
//!    drop path).
//!
//! Signal handling is a raw `signal(2)` FFI binding (no libc crate):
//! the handler only stores into a static `AtomicBool`, which the serve
//! loop polls — the async-signal-safe minimum.

use std::io;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::serve::conn::{handle_conn, ServeState};

/// How long the accept loop sleeps when there is nothing to accept.
const ACCEPT_IDLE: Duration = Duration::from_millis(2);

/// A running server: bound address plus the handles needed to stop it.
pub struct ServeHandle {
    addr: SocketAddr,
    state: Arc<ServeState>,
    stop: Arc<AtomicBool>,
    acceptor: Option<thread::JoinHandle<()>>,
}

/// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
/// accepting. Returns once the socket is listening.
pub fn serve(addr: &str, state: Arc<ServeState>) -> io::Result<ServeHandle> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let acceptor = {
        let state = Arc::clone(&state);
        let stop = Arc::clone(&stop);
        thread::Builder::new()
            .name("cer-serve-accept".to_string())
            .spawn(move || accept_loop(listener, state, stop))
            .expect("spawn accept loop")
    };
    Ok(ServeHandle {
        addr: local,
        state,
        stop,
        acceptor: Some(acceptor),
    })
}

fn accept_loop(listener: TcpListener, state: Arc<ServeState>, stop: Arc<AtomicBool>) {
    loop {
        if stop.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                // The connection socket is blocking with a short read
                // timeout; handle_conn polls `stop` between requests.
                let _ = stream.set_nonblocking(false);
                let state = Arc::clone(&state);
                let stop = Arc::clone(&stop);
                let _ = thread::Builder::new()
                    .name("cer-serve-conn".to_string())
                    .spawn(move || handle_conn(stream, &state, &stop));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => thread::sleep(ACCEPT_IDLE),
            Err(_) => thread::sleep(ACCEPT_IDLE),
        }
    }
}

impl ServeHandle {
    /// The actually bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shared server state (metrics, admission, router).
    pub fn state(&self) -> &Arc<ServeState> {
        &self.state
    }

    /// Stop admitting new inference requests; health/metrics stay up.
    pub fn begin_drain(&self) {
        self.state.begin_drain();
    }

    /// True once an admin `/admin/shutdown` request has been served.
    pub fn shutdown_requested(&self) -> bool {
        self.state.shutdown_requested.load(Ordering::Acquire)
    }

    /// Graceful shutdown: stop accepting, wait (up to `timeout`) for all
    /// in-flight requests to be answered, then drain the worker plane.
    /// Returns `true` when everything finished inside the timeout.
    pub fn shutdown(mut self, timeout: Duration) -> bool {
        self.begin_drain();
        self.stop.store(true, Ordering::Release);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        let deadline = Instant::now() + timeout;
        let mut clean = true;
        while self.state.admission.inflight() > 0 {
            if Instant::now() >= deadline {
                clean = false;
                break;
            }
            thread::sleep(Duration::from_millis(5));
        }
        // Flush batchers and join worker threads. Connection threads
        // notice `stop` within their 250ms read timeout and exit on
        // their own; they hold no endpoint references while idle.
        self.state.router.shutdown();
        clean
    }
}

impl Drop for ServeHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
    }
}

#[cfg(unix)]
mod sig {
    use std::os::raw::c_int;
    use std::sync::atomic::{AtomicBool, Ordering};

    pub static TERM: AtomicBool = AtomicBool::new(false);

    type SigHandler = extern "C" fn(c_int);

    extern "C" {
        /// POSIX `signal(2)` — bound directly to avoid a libc dep. The
        /// return value (previous handler) is deliberately opaque.
        fn signal(signum: c_int, handler: SigHandler) -> usize;
    }

    extern "C" fn on_term(_sig: c_int) {
        // Only an atomic store: the async-signal-safe whitelist.
        TERM.store(true, Ordering::SeqCst);
    }

    const SIGINT: c_int = 2;
    const SIGTERM: c_int = 15;

    pub fn install() {
        unsafe {
            signal(SIGTERM, on_term);
            signal(SIGINT, on_term);
        }
    }
}

/// Arm the SIGTERM/SIGINT → drain flag. Safe to call more than once.
pub fn install_term_handler() {
    #[cfg(unix)]
    sig::install();
}

/// True once SIGTERM or SIGINT has been delivered (always false on
/// non-unix, where only admin-endpoint shutdown is available).
pub fn termination_requested() -> bool {
    #[cfg(unix)]
    {
        sig::TERM.load(std::sync::atomic::Ordering::SeqCst)
    }
    #[cfg(not(unix))]
    {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::engine::Engine;
    use crate::coordinator::server::ServerConfig;
    use crate::formats::{Dense, FormatKind};
    use crate::serve::conn::ServeOptions;
    use crate::serve::http::{HttpClient, Request};
    use crate::serve::reload::HotRouter;
    use crate::util::rng::Rng;

    fn spawn_server() -> (ServeHandle, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!("listener-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("listener.cerpack");
        let mut rng = Rng::new(5);
        let d = Dense::from_vec(3, 5, (0..15).map(|_| rng.f32() - 0.5).collect());
        let e = Engine::native_fixed(vec![("fc".to_string(), d, vec![0.0; 3])], FormatKind::Cser);
        e.save_pack(&path, "net", "test").unwrap();
        let cfg = ServerConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_delay_us: 100,
            },
            threads: Some(1),
            ..ServerConfig::default()
        };
        let router = HotRouter::new(cfg, 1);
        router.add_pack("net", &path).unwrap();
        let state = ServeState::new(router, ServeOptions::default());
        let handle = serve("127.0.0.1:0", state).unwrap();
        (handle, path)
    }

    #[test]
    fn accepts_requests_and_shuts_down_cleanly() {
        let (handle, path) = spawn_server();
        let addr = handle.addr().to_string();
        let mut client = HttpClient::connect(&addr, Duration::from_secs(2)).unwrap();
        let health = client
            .request(&Request::new("GET", "/healthz"))
            .unwrap();
        assert_eq!(health.status, 200);
        assert!(health.body_str().contains("\"net\""));
        let infer = client
            .request(
                &Request::new("POST", "/v1/infer").json("{\"input\":[1,0,1,0,1]}".to_string()),
            )
            .unwrap();
        assert_eq!(infer.status, 200, "{}", infer.body_str());
        assert!(handle.shutdown(Duration::from_secs(5)), "drain timed out");
        // Socket must be gone.
        assert!(HttpClient::connect(&addr, Duration::from_millis(300)).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn keep_alive_connection_survives_multiple_requests() {
        let (handle, path) = spawn_server();
        let mut client =
            HttpClient::connect(&handle.addr().to_string(), Duration::from_secs(2)).unwrap();
        let mut bodies = Vec::new();
        for _ in 0..5 {
            let r = client
                .request(
                    &Request::new("POST", "/v1/infer")
                        .json("{\"input\":[0.5,0.5,0.5,0.5,0.5]}".to_string()),
                )
                .unwrap();
            assert_eq!(r.status, 200);
            bodies.push(r.body_str().into_owned());
        }
        assert!(bodies.windows(2).all(|w| w[0] == w[1]), "nondeterministic replies");
        assert!(handle.shutdown(Duration::from_secs(5)));
        let _ = std::fs::remove_file(&path);
    }
}
