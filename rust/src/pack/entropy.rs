//! Dependency-free canonical Huffman codec for the `.cerpack` entropy
//! tier.
//!
//! The paper's bound says a layer's storage should track `N·H` — the
//! element count times the element entropy — yet the raw pack tier stores
//! index arrays at fixed minimal widths (8/16/32 bits), paying the gap
//! between `⌈log₂ K⌉` and `H`. Deep Compression (Han et al., PAPERS.md)
//! closes exactly that gap with Huffman codes over the quantized
//! representation; this module is that coder, specialized to the pack's
//! integer arrays (codebook indices, column indices, pointers — float
//! arrays pass through raw).
//!
//! Design:
//!
//! * **Format-agnostic span discovery.** Rather than teach six formats
//!   how to entropy-code themselves, [`payload_spans`] replays a raw
//!   payload through a recording [`ArrayLoader`]: every bulk array a
//!   decoder reads is reported as an [`ArraySpan`] (offset, element
//!   width, count). Integer spans become candidate symbol streams; the
//!   bytes between spans (scalar headers, padding) and float spans pass
//!   through verbatim. A seventh format inherits the tier for free.
//! * **Canonical codes, length-limited to [`MAX_CODE_LEN`].** Code
//!   lengths come from a standard two-queue Huffman build; overdeep
//!   trees are reshaped by the Kraft-preserving counts adjustment (the
//!   zlib/miniz technique), then codes are assigned canonically in
//!   (length, symbol) order — so a code book serializes as nothing but
//!   one `u8` length per symbol.
//! * **Never larger than raw.** Every stream is coded only if its coded
//!   bytes plus its share of (new) table bytes undercut the raw bytes;
//!   otherwise it is stored raw. Coded on-disk bytes are therefore ≤ raw
//!   bytes by construction, stream by stream.
//! * **Pack-level code-book dedup.** Identical length tables (layers
//!   quantized against the same codebook produce them constantly) are
//!   interned in a [`CodebookSet`] and referenced by id, so a table is
//!   paid for once per pack, not once per layer.
//!
//! Decoding reconstructs the exact raw payload bytes (coded streams are
//! re-narrowed to their original element width), then hands the result to
//! the ordinary raw decoder — bit-identity with the raw tier holds by
//! construction, for every format.

use std::collections::HashMap;

use super::wire::{put_u32, put_u64, ArrayLoader, ArraySpan, Cursor, SpanRecorder};
use super::PackError;
use crate::kernels::AnyMatrix;

/// Longest admissible canonical code, in bits. 16 keeps the decode
/// accumulator comfortably in `u32`, bounds the per-symbol decode loop,
/// and hosts up to 65536 distinct symbols — far beyond any codebook or
/// column alphabet the formats produce (streams with more distinct
/// symbols fall back to raw storage).
pub const MAX_CODE_LEN: usize = 16;

/// Stream kind tag: structural bytes (scalar headers, padding, float
/// arrays) stored verbatim.
pub(crate) const STREAM_RAW: u8 = 0;
/// Stream kind tag: a Huffman-coded integer array.
pub(crate) const STREAM_CODED: u8 = 1;
/// Stream kind tag: an integer array stored verbatim because coding did
/// not pay for itself. Decodes exactly like [`STREAM_RAW`]; kept distinct
/// so the on-disk accounting can compare array bytes (coded + fallback)
/// against the raw tier's `array_bytes` without re-deriving spans.
pub(crate) const STREAM_RAW_ARRAY: u8 = 2;

/// Fixed wire overhead of a coded stream record (kind, width, table id,
/// symbol count, coded byte length) — part of the pay-for-itself test.
const CODED_STREAM_OVERHEAD: usize = 1 + 1 + 4 + 4 + 8;
/// Fixed wire overhead of a raw stream record (kind, byte length).
const RAW_STREAM_OVERHEAD: usize = 1 + 8;

/// A canonical Huffman code book over `u32` symbols: one code length per
/// symbol (0 = symbol absent). Codes are implied — assigned canonically
/// in (length, symbol) order — so this is also the wire representation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CodeBook {
    lens: Vec<u8>,
}

impl CodeBook {
    /// Build a length-limited code book from per-symbol frequencies
    /// (index = symbol). Returns `None` when no symbol occurs or when
    /// more than `2^MAX_CODE_LEN` distinct symbols would need codes.
    pub fn from_frequencies(freq: &[u64]) -> Option<CodeBook> {
        let mut present: Vec<(u64, u32)> = freq
            .iter()
            .enumerate()
            .filter(|&(_, &f)| f > 0)
            .map(|(s, &f)| (f, s as u32))
            .collect();
        if present.is_empty() || present.len() > (1 << MAX_CODE_LEN) {
            return None;
        }
        let mut lens = vec![0u8; freq.len()];
        if present.len() == 1 {
            // A degenerate one-symbol alphabet still gets a 1-bit code so
            // the stream stays uniform (and 8× smaller than raw u8s).
            lens[present[0].1 as usize] = 1;
            return Some(CodeBook { lens });
        }
        present.sort(); // ascending (frequency, symbol) — deterministic
        let count = length_counts(&present);
        // Hand the shortest lengths to the most frequent symbols
        // (ties broken by symbol for determinism).
        let mut by_freq = present;
        by_freq.sort_by(|a, b| (b.0, a.1).cmp(&(a.0, b.1)));
        let mut i = 0;
        for (l, &c) in count.iter().enumerate().skip(1) {
            for _ in 0..c {
                lens[by_freq[i].1 as usize] = l as u8;
                i += 1;
            }
        }
        debug_assert_eq!(i, by_freq.len());
        Some(CodeBook { lens })
    }

    /// Total coded bits this book spends on a stream with the given
    /// per-symbol frequencies.
    pub fn cost_bits(&self, freq: &[u64]) -> u64 {
        freq.iter()
            .zip(&self.lens)
            .map(|(&f, &l)| f * l as u64)
            .sum()
    }

    /// Serialized wire size in bytes (`u32` alphabet + one `u8` per
    /// symbol).
    pub fn wire_bytes(&self) -> usize {
        4 + self.lens.len()
    }

    /// Append the wire form: `u32` alphabet size, then the length bytes.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        put_u32(out, self.lens.len() as u32);
        out.extend_from_slice(&self.lens);
    }

    /// Parse and structurally validate one code book.
    pub fn decode_from(cur: &mut Cursor<'_>) -> Result<CodeBook, PackError> {
        let alphabet = cur.u32_len("codebook alphabet size")?;
        if alphabet == 0 || alphabet > MAX_ALPHABET {
            return Err(PackError::malformed(format!(
                "implausible codebook alphabet size {alphabet}"
            )));
        }
        let lens = cur.take(alphabet)?.to_vec();
        let book = CodeBook { lens };
        book.decoder()?; // rejects over-long / oversubscribed tables
        Ok(book)
    }

    /// Per-symbol canonical codes for encoding. Fails on a structurally
    /// invalid length table (decoded books are pre-validated; fresh books
    /// are correct by construction).
    fn codes(&self) -> Result<Vec<(u32, u8)>, PackError> {
        let (_, first_code) = canonical_geometry(&self.lens)?;
        let mut next = first_code;
        let mut codes = vec![(0u32, 0u8); self.lens.len()];
        for (sym, &l) in self.lens.iter().enumerate() {
            if l > 0 {
                codes[sym] = (next[l as usize], l);
                next[l as usize] += 1;
            }
        }
        Ok(codes)
    }

    /// Build the canonical decoding tables.
    pub fn decoder(&self) -> Result<Decoder, PackError> {
        let (count, first_code) = canonical_geometry(&self.lens)?;
        let mut first_idx = [0u32; MAX_CODE_LEN + 1];
        let mut idx = 0u32;
        for l in 1..=MAX_CODE_LEN {
            first_idx[l] = idx;
            idx += count[l];
        }
        // Symbols in (length, symbol) order — symbol order is ascending
        // per length because we scan symbols in ascending order.
        let mut syms = vec![0u32; idx as usize];
        let mut next = first_idx;
        for (sym, &l) in self.lens.iter().enumerate() {
            if l > 0 {
                syms[next[l as usize] as usize] = sym as u32;
                next[l as usize] += 1;
            }
        }
        Ok(Decoder {
            count,
            first_code,
            first_idx,
            syms,
        })
    }
}

/// Cap on serialized codebook alphabets: a table is one byte per symbol,
/// so this bounds hostile allocations at 1 MiB while admitting any
/// realistic column/codebook alphabet.
const MAX_ALPHABET: usize = 1 << 20;

/// Two-queue Huffman over `present` (sorted ascending by (freq, sym)),
/// returning code-length counts per length, reshaped to respect
/// [`MAX_CODE_LEN`] while keeping the Kraft sum exact.
fn length_counts(present: &[(u64, u32)]) -> [u32; MAX_CODE_LEN + 1] {
    let n = present.len();
    debug_assert!(n >= 2);
    // Node arena: leaves 0..n, then n-1 internal nodes. Weights of
    // internal nodes are created in nondecreasing order, so two cursors
    // (next unconsumed leaf, next unconsumed internal) always expose the
    // two global minima at their fronts.
    let mut weight: Vec<u64> = present.iter().map(|&(f, _)| f).collect();
    let mut parent: Vec<usize> = vec![usize::MAX; 2 * n - 1];
    weight.reserve(n - 1);
    let (mut leaf, mut inner) = (0usize, n);
    for _ in 0..n - 1 {
        let mut take = || {
            // Prefer the leaf queue on ties: marginally flatter trees,
            // and a deterministic shape either way.
            if leaf < n && (inner >= weight.len() || weight[leaf] <= weight[inner]) {
                leaf += 1;
                leaf - 1
            } else {
                inner += 1;
                inner - 1
            }
        };
        let (a, b) = (take(), take());
        let node = weight.len();
        weight.push(weight[a].saturating_add(weight[b]));
        parent[a] = node;
        parent[b] = node;
    }
    let root = weight.len() - 1;
    let mut count = [0u32; MAX_CODE_LEN + 1];
    let mut total: u64 = 0;
    for i in 0..n {
        let mut depth = 0usize;
        let mut at = i;
        while at != root {
            at = parent[at];
            depth += 1;
        }
        let depth = depth.min(MAX_CODE_LEN);
        count[depth] += 1;
        total += 1u64 << (MAX_CODE_LEN - depth);
    }
    // Clamping overfilled the code space; move codes up the tree until
    // the Kraft sum is exact again (zlib's length-limiting step).
    let target = 1u64 << MAX_CODE_LEN;
    while total > target {
        count[MAX_CODE_LEN] -= 1;
        for l in (1..MAX_CODE_LEN).rev() {
            if count[l] > 0 {
                count[l] -= 1;
                count[l + 1] += 2;
                break;
            }
        }
        total -= 1;
    }
    count
}

/// Per-length code counts and canonical first codes for a length table,
/// rejecting oversubscribed levels (Kraft violations) so hostile tables
/// can never make canonical decode ambiguous.
fn canonical_geometry(
    lens: &[u8],
) -> Result<([u32; MAX_CODE_LEN + 1], [u32; MAX_CODE_LEN + 1]), PackError> {
    let mut count = [0u32; MAX_CODE_LEN + 1];
    for &l in lens {
        if l as usize > MAX_CODE_LEN {
            return Err(PackError::malformed(format!(
                "huffman code length {l} exceeds the {MAX_CODE_LEN}-bit limit"
            )));
        }
        if l > 0 {
            count[l as usize] += 1;
        }
    }
    let mut first_code = [0u32; MAX_CODE_LEN + 1];
    let mut code = 0u64;
    for l in 1..=MAX_CODE_LEN {
        code = (code + count[l - 1] as u64) << 1;
        first_code[l] = code as u32;
        let end = code + count[l] as u64;
        if end > 1u64 << l {
            return Err(PackError::malformed(
                "oversubscribed huffman length table".to_string(),
            ));
        }
    }
    Ok((count, first_code))
}

/// Canonical decoding tables built from a validated [`CodeBook`].
#[derive(Clone, Debug)]
pub struct Decoder {
    count: [u32; MAX_CODE_LEN + 1],
    first_code: [u32; MAX_CODE_LEN + 1],
    first_idx: [u32; MAX_CODE_LEN + 1],
    syms: Vec<u32>,
}

impl Decoder {
    /// Decode one symbol, MSB-first.
    fn symbol(&self, bits: &mut BitReader<'_>) -> Result<u32, PackError> {
        let mut code = 0u32;
        for l in 1..=MAX_CODE_LEN {
            code = (code << 1) | bits.bit()?;
            let c = self.count[l];
            if c > 0 && code >= self.first_code[l] && code - self.first_code[l] < c {
                let i = self.first_idx[l] + (code - self.first_code[l]);
                return Ok(self.syms[i as usize]);
            }
        }
        Err(PackError::malformed("invalid huffman code".to_string()))
    }
}

/// MSB-first bit appender.
struct BitWriter {
    out: Vec<u8>,
    acc: u32,
    nbits: u32,
}

impl BitWriter {
    fn new() -> BitWriter {
        BitWriter {
            out: Vec::new(),
            acc: 0,
            nbits: 0,
        }
    }

    fn put(&mut self, code: u32, len: u8) {
        debug_assert!(len >= 1 && len as usize <= MAX_CODE_LEN);
        self.acc = (self.acc << len) | code;
        self.nbits += len as u32;
        while self.nbits >= 8 {
            self.nbits -= 8;
            self.out.push((self.acc >> self.nbits) as u8);
        }
    }

    /// Flush, zero-padding the final partial byte.
    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.out.push((self.acc << (8 - self.nbits)) as u8);
        }
        self.out
    }
}

/// Bounds-checked MSB-first bit reader; running out of bytes is a
/// malformed-stream error, never a panic.
struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    acc: u32,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    fn new(buf: &'a [u8]) -> BitReader<'a> {
        BitReader {
            buf,
            pos: 0,
            acc: 0,
            nbits: 0,
        }
    }

    fn bit(&mut self) -> Result<u32, PackError> {
        if self.nbits == 0 {
            if self.pos >= self.buf.len() {
                return Err(PackError::malformed(
                    "huffman stream ends mid-symbol".to_string(),
                ));
            }
            self.acc = self.buf[self.pos] as u32;
            self.pos += 1;
            self.nbits = 8;
        }
        self.nbits -= 1;
        Ok((self.acc >> self.nbits) & 1)
    }

    /// Bytes consumed so far (the current partial byte counts).
    fn bytes_consumed(&self) -> usize {
        self.pos
    }
}

/// Pack-level interning of code books: identical length tables are stored
/// once and referenced by id from every coded stream that uses them.
/// `Clone` is cheap (a handful of small length tables) — the streaming
/// writer trial-encodes each layer against a clone and commits it only
/// when the coded tier wins, so rejected layers never leave stray tables
/// in the shared section.
#[derive(Clone, Default)]
pub struct CodebookSet {
    books: Vec<CodeBook>,
    index: HashMap<Vec<u8>, u32>,
}

impl CodebookSet {
    pub fn new() -> CodebookSet {
        CodebookSet::default()
    }

    pub fn is_empty(&self) -> bool {
        self.books.is_empty()
    }

    pub fn len(&self) -> usize {
        self.books.len()
    }

    /// Wire bytes the book would add if interned now (0 when an identical
    /// table is already present).
    fn marginal_bytes(&self, book: &CodeBook) -> usize {
        if self.index.contains_key(&book.lens) {
            0
        } else {
            book.wire_bytes()
        }
    }

    fn intern(&mut self, book: CodeBook) -> u32 {
        if let Some(&id) = self.index.get(&book.lens) {
            return id;
        }
        let id = self.books.len() as u32;
        self.index.insert(book.lens.clone(), id);
        self.books.push(book);
        id
    }

    /// Serialize the whole set as a `SECTION_CODEBOOKS` payload
    /// (`u32` table count, then the tables in id order).
    pub fn encode_section(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u32(&mut out, self.books.len() as u32);
        for b in &self.books {
            b.encode_into(&mut out);
        }
        out
    }
}

/// Parse a `SECTION_CODEBOOKS` payload into ready decoding tables.
pub fn decode_codebooks(buf: &[u8]) -> Result<Vec<Decoder>, PackError> {
    let mut cur = Cursor::new(buf);
    let n = cur.u32_len("codebook count")?;
    if n > cur.remaining() {
        return Err(PackError::malformed(format!(
            "implausible codebook count {n}"
        )));
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(CodeBook::decode_from(&mut cur)?.decoder()?);
    }
    if cur.remaining() != 0 {
        return Err(PackError::malformed(
            "trailing bytes after codebooks".to_string(),
        ));
    }
    Ok(out)
}

/// Replay a raw payload through a recording loader to discover every bulk
/// array mechanically — no per-format knowledge. Returns the spans in
/// ascending offset order; the decode also revalidates the payload.
pub(crate) fn payload_spans(payload: &[u8]) -> Result<Vec<ArraySpan>, PackError> {
    let rec = SpanRecorder::new();
    AnyMatrix::decode_from_source(payload, ArrayLoader::recording(&rec))?;
    let mut spans: Vec<ArraySpan> = rec
        .into_spans()
        .into_iter()
        .filter(|s| s.count > 0)
        .collect();
    spans.sort_by_key(|s| s.offset);
    for w in spans.windows(2) {
        if w[0].offset + w[0].byte_len() > w[1].offset {
            // Decoders read strictly forward, so overlap means the
            // recorder itself is wrong — refuse to code rather than
            // write a section that cannot reconstruct.
            return Err(PackError::malformed(
                "overlapping array spans recorded during entropy encode".to_string(),
            ));
        }
    }
    Ok(spans)
}

fn span_symbols(payload: &[u8], span: &ArraySpan) -> Vec<u32> {
    let bytes = &payload[span.offset..span.offset + span.byte_len()];
    match span.width {
        1 => bytes.iter().map(|&b| b as u32).collect(),
        2 => bytes
            .chunks_exact(2)
            .map(|b| u16::from_le_bytes([b[0], b[1]]) as u32)
            .collect(),
        _ => bytes
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect(),
    }
}

/// The stream list of one coded layer section, plus its accounting.
pub(crate) struct EncodedStreams {
    /// Wire bytes: `u32` stream count, then the stream records.
    pub bytes: Vec<u8>,
    /// On-disk bytes of the array spans (Huffman-coded plus raw
    /// fallback) — the figure `repro inspect` compares against the raw
    /// tier's `array_bytes` and the `N*H` bound.
    pub array_disk_bytes: u64,
    /// Streams that took the Huffman path.
    pub coded_streams: usize,
}

/// Split a raw payload into streams and Huffman-code every integer array
/// stream that pays for itself (including its share of new table bytes);
/// everything else is stored verbatim. Deterministic for a given payload
/// and `books` state.
pub(crate) fn encode_streams(
    payload: &[u8],
    books: &mut CodebookSet,
) -> Result<EncodedStreams, PackError> {
    let spans = payload_spans(payload)?;
    // Assemble the stream plan first: raw gaps between spans, and a
    // per-span coded/raw decision.
    enum Plan {
        Raw { from: usize, to: usize },
        RawArray { from: usize, to: usize },
        Coded { book: CodeBook, id_hint: Option<u32>, span: ArraySpan },
    }
    let mut plan: Vec<Plan> = Vec::new();
    let mut pos = 0usize;
    let mut push_raw = |plan: &mut Vec<Plan>, from: usize, to: usize| {
        if to > from {
            // Merge adjacent raw runs so structural gaps and fallback
            // arrays don't fragment into needless stream records.
            if let Some(Plan::Raw { to: prev_to, .. }) = plan.last_mut() {
                *prev_to = to;
                return;
            }
            plan.push(Plan::Raw { from, to });
        }
    };
    for span in spans {
        if span.offset > pos {
            push_raw(&mut plan, pos, span.offset);
        }
        pos = span.offset + span.byte_len();
        if span.float || span.count > u32::MAX as usize {
            push_raw(&mut plan, span.offset, pos);
            continue;
        }
        let raw_len = span.byte_len();
        let syms = span_symbols(payload, &span);
        let max_sym = *syms.iter().max().expect("non-empty span") as usize;
        // A table stores one byte per alphabet slot — bail before even
        // counting frequencies when the alphabet alone dwarfs the data.
        if max_sym >= MAX_ALPHABET || max_sym + 1 > 8 * raw_len {
            plan.push(Plan::RawArray { from: span.offset, to: pos });
            continue;
        }
        let mut freq = vec![0u64; max_sym + 1];
        for &s in &syms {
            freq[s as usize] += 1;
        }
        let Some(book) = CodeBook::from_frequencies(&freq) else {
            plan.push(Plan::RawArray { from: span.offset, to: pos });
            continue;
        };
        let coded_len = (book.cost_bits(&freq) as usize).div_ceil(8);
        let table_cost = books.marginal_bytes(&book);
        if coded_len + CODED_STREAM_OVERHEAD + table_cost
            < raw_len + RAW_STREAM_OVERHEAD
        {
            let id_hint = books.index.get(&book.lens).copied();
            plan.push(Plan::Coded { book, id_hint, span });
        } else {
            plan.push(Plan::RawArray { from: span.offset, to: pos });
        }
    }
    if payload.len() > pos {
        push_raw(&mut plan, pos, payload.len());
    }

    let mut out = Vec::new();
    put_u32(&mut out, plan.len() as u32);
    let mut array_disk_bytes = 0u64;
    let mut coded_streams = 0usize;
    for step in plan {
        match step {
            Plan::Raw { from, to } => {
                out.push(STREAM_RAW);
                put_u64(&mut out, (to - from) as u64);
                out.extend_from_slice(&payload[from..to]);
            }
            Plan::RawArray { from, to } => {
                out.push(STREAM_RAW_ARRAY);
                put_u64(&mut out, (to - from) as u64);
                out.extend_from_slice(&payload[from..to]);
                array_disk_bytes += (to - from) as u64;
            }
            Plan::Coded { book, id_hint, span } => {
                let codes = book.codes()?;
                let id = match id_hint {
                    Some(id) => id,
                    None => books.intern(book),
                };
                let mut bits = BitWriter::new();
                for &s in &span_symbols(payload, &span) {
                    let (code, len) = codes[s as usize];
                    bits.put(code, len);
                }
                let coded = bits.finish();
                out.push(STREAM_CODED);
                out.push(span.width as u8);
                put_u32(&mut out, id);
                put_u32(&mut out, span.count as u32);
                put_u64(&mut out, coded.len() as u64);
                out.extend_from_slice(&coded);
                array_disk_bytes += coded.len() as u64;
                coded_streams += 1;
            }
        }
    }
    Ok(EncodedStreams {
        bytes: out,
        array_disk_bytes,
        coded_streams,
    })
}

/// A reconstructed raw payload plus the accounting of the coded bytes it
/// came from.
pub(crate) struct DecodedStreams {
    pub payload: Vec<u8>,
    pub array_disk_bytes: u64,
    pub coded_streams: usize,
}

/// Inverse of [`encode_streams`]: read the stream list from `cur` and
/// reconstruct the exact raw payload bytes. `max_len` bounds the
/// reconstruction (the declared raw payload length) so corrupt counts
/// cannot balloon memory.
pub(crate) fn decode_streams(
    cur: &mut Cursor<'_>,
    books: &[Decoder],
    max_len: usize,
) -> Result<DecodedStreams, PackError> {
    let n_streams = cur.u32_len("stream count")?;
    let mut payload: Vec<u8> = Vec::new();
    let mut array_disk_bytes = 0u64;
    let mut coded_streams = 0usize;
    for _ in 0..n_streams {
        match cur.u8()? {
            k @ (STREAM_RAW | STREAM_RAW_ARRAY) => {
                let len = cur.u64_len("raw stream length")?;
                let bytes = cur.take(len)?;
                if payload.len() + len > max_len {
                    return Err(PackError::malformed(
                        "streams overrun the declared payload length".to_string(),
                    ));
                }
                payload.extend_from_slice(bytes);
                if k == STREAM_RAW_ARRAY {
                    array_disk_bytes += len as u64;
                }
            }
            STREAM_CODED => {
                let width = cur.u8()? as usize;
                if !matches!(width, 1 | 2 | 4) {
                    return Err(PackError::malformed(format!(
                        "coded stream has invalid element width {width}"
                    )));
                }
                let id = cur.u32_len("codebook id")?;
                let count = cur.u32_len("coded symbol count")?;
                let coded_len = cur.u64_len("coded stream length")?;
                let coded = cur.take(coded_len)?;
                let dec = books.get(id).ok_or_else(|| {
                    PackError::malformed(format!("coded stream references unknown codebook {id}"))
                })?;
                let decoded_len = count
                    .checked_mul(width)
                    .ok_or_else(|| PackError::malformed("coded stream size overflow"))?;
                if payload.len() + decoded_len > max_len {
                    return Err(PackError::malformed(
                        "streams overrun the declared payload length".to_string(),
                    ));
                }
                let mut bits = BitReader::new(coded);
                for _ in 0..count {
                    let sym = dec.symbol(&mut bits)?;
                    if width < 4 && sym >> (8 * width) != 0 {
                        return Err(PackError::malformed(format!(
                            "decoded symbol {sym} does not fit a {width}-byte element"
                        )));
                    }
                    payload.extend_from_slice(&sym.to_le_bytes()[..width]);
                }
                if bits.bytes_consumed() != coded.len() {
                    return Err(PackError::malformed(
                        "coded stream has trailing bytes".to_string(),
                    ));
                }
                array_disk_bytes += coded_len as u64;
                coded_streams += 1;
            }
            other => {
                return Err(PackError::malformed(format!(
                    "unknown stream kind {other}"
                )))
            }
        }
    }
    Ok(DecodedStreams {
        payload,
        array_disk_bytes,
        coded_streams,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::FormatKind;
    use crate::util::Rng;

    fn roundtrip(syms: &[u32], width: usize) {
        let max = *syms.iter().max().unwrap() as usize;
        let mut freq = vec![0u64; max + 1];
        for &s in syms {
            freq[s as usize] += 1;
        }
        let book = CodeBook::from_frequencies(&freq).expect("book");
        let codes = book.codes().unwrap();
        let mut bits = BitWriter::new();
        for &s in syms {
            let (c, l) = codes[s as usize];
            assert!(l >= 1, "present symbol {s} must have a code");
            bits.put(c, l);
        }
        let coded = bits.finish();
        assert_eq!(coded.len(), (book.cost_bits(&freq) as usize).div_ceil(8));
        let dec = book.decoder().unwrap();
        let mut rd = BitReader::new(&coded);
        let back: Vec<u32> = (0..syms.len()).map(|_| dec.symbol(&mut rd).unwrap()).collect();
        assert_eq!(back, syms);
        assert_eq!(rd.bytes_consumed(), coded.len());
        // Round-trip through the wire form too.
        let mut wire = Vec::new();
        book.encode_into(&mut wire);
        assert_eq!(wire.len(), book.wire_bytes());
        let back_book = CodeBook::decode_from(&mut Cursor::new(&wire)).unwrap();
        assert_eq!(back_book, book);
        let _ = width;
    }

    #[test]
    fn skewed_stream_roundtrips_below_fixed_width() {
        let mut rng = Rng::new(0xC0DE);
        // Zipf-ish skew over 17 symbols.
        let syms: Vec<u32> = (0..4096)
            .map(|_| {
                let r = rng.below(100);
                if r < 60 {
                    0
                } else if r < 80 {
                    1
                } else {
                    2 + rng.below(15) as u32
                }
            })
            .collect();
        roundtrip(&syms, 1);
        let max = *syms.iter().max().unwrap() as usize;
        let mut freq = vec![0u64; max + 1];
        for &s in &syms {
            freq[s as usize] += 1;
        }
        let book = CodeBook::from_frequencies(&freq).unwrap();
        // A skewed distribution must beat the 8-bit raw width.
        assert!(book.cost_bits(&freq) < 8 * syms.len() as u64);
    }

    #[test]
    fn single_symbol_stream_is_one_bit_per_element() {
        let syms = vec![7u32; 300];
        roundtrip(&syms, 2);
        let mut freq = vec![0u64; 8];
        freq[7] = 300;
        let book = CodeBook::from_frequencies(&freq).unwrap();
        assert_eq!(book.cost_bits(&freq), 300);
    }

    #[test]
    fn fibonacci_frequencies_respect_the_length_limit() {
        // Fibonacci weights build maximally skewed Huffman trees — depth
        // would exceed MAX_CODE_LEN without the limiting step.
        let mut freq = vec![0u64; 24];
        let (mut a, mut b) = (1u64, 1u64);
        for f in freq.iter_mut() {
            *f = a;
            let c = a + b;
            a = b;
            b = c;
        }
        let book = CodeBook::from_frequencies(&freq).unwrap();
        assert!(book.lens.iter().all(|&l| (l as usize) <= MAX_CODE_LEN));
        // Still a prefix code after limiting: encode/decode every symbol
        // through the reshaped tree.
        let codes = book.codes().unwrap();
        let syms: Vec<u32> = (0..24).collect();
        let mut bits = BitWriter::new();
        for &s in &syms {
            let (c, l) = codes[s as usize];
            bits.put(c, l);
        }
        let coded = bits.finish();
        let dec = book.decoder().unwrap();
        let mut rd = BitReader::new(&coded);
        let back: Vec<u32> = (0..24).map(|_| dec.symbol(&mut rd).unwrap()).collect();
        assert_eq!(back, syms);
    }

    #[test]
    fn oversubscribed_table_is_rejected() {
        // Three 1-bit codes violate Kraft.
        let book = CodeBook { lens: vec![1, 1, 1] };
        assert!(book.decoder().is_err());
        let mut wire = Vec::new();
        book.encode_into(&mut wire);
        assert!(CodeBook::decode_from(&mut Cursor::new(&wire)).is_err());
        // Over-long lengths are rejected too.
        let book = CodeBook { lens: vec![1, 17] };
        assert!(book.decoder().is_err());
    }

    #[test]
    fn truncated_and_invalid_streams_error_cleanly() {
        let mut freq = vec![0u64; 3];
        freq[0] = 5;
        freq[1] = 3;
        freq[2] = 1;
        let book = CodeBook::from_frequencies(&freq).unwrap();
        let dec = book.decoder().unwrap();
        // Empty stream: first symbol read fails.
        let mut rd = BitReader::new(&[]);
        assert!(dec.symbol(&mut rd).is_err());
        // An all-ones byte eventually walks past every level of an
        // incomplete tree or runs out of bits — error either way.
        let mut rd = BitReader::new(&[0xFF]);
        let mut got_err = false;
        for _ in 0..16 {
            if dec.symbol(&mut rd).is_err() {
                got_err = true;
                break;
            }
        }
        let _ = got_err; // decoding may legitimately yield symbols first
    }

    #[test]
    fn recorded_spans_cover_exactly_the_accounted_array_bytes() {
        // The recorder must discover precisely the bytes the formats
        // account as "array bytes" — the invariant the whole tier
        // stands on. (Emitted.arrays == analytic bits / 8 is already
        // asserted by the pack tests.)
        let m = crate::paper_example_matrix();
        for kind in FormatKind::ALL {
            let any = AnyMatrix::encode(kind, &m);
            let mut payload = Vec::new();
            let emitted = any.encode_into(&mut payload);
            let spans = payload_spans(&payload).expect("spans");
            let covered: usize = spans.iter().map(|s| s.byte_len()).sum();
            assert_eq!(
                covered, emitted.arrays,
                "{kind:?}: recorded spans must cover the accounted arrays"
            );
            for s in &spans {
                assert!(s.offset + s.byte_len() <= payload.len());
            }
        }
    }

    #[test]
    fn stream_encode_reconstructs_every_format_bit_identically() {
        let mut rng = Rng::new(0xBEEF);
        let values = [0.0f32, 0.0, 0.0, 0.5, -0.5, 1.5];
        let data: Vec<f32> = (0..48 * 31).map(|_| values[rng.below(6)]).collect();
        let m = crate::formats::Dense::from_vec(48, 31, data);
        let mut books = CodebookSet::new();
        let mut blobs = Vec::new();
        for kind in FormatKind::ALL {
            let any = AnyMatrix::encode(kind, &m);
            let mut payload = Vec::new();
            any.encode_into(&mut payload);
            let enc = encode_streams(&payload, &mut books).expect("encode");
            blobs.push((kind, payload, enc));
        }
        let decs: Vec<Decoder> = {
            let sec = books.encode_section();
            decode_codebooks(&sec).expect("codebooks")
        };
        for (kind, payload, enc) in blobs {
            let mut cur = Cursor::new(&enc.bytes);
            let dec = decode_streams(&mut cur, &decs, payload.len()).expect("decode");
            assert_eq!(cur.remaining(), 0);
            assert_eq!(dec.payload, payload, "{kind:?}: reconstruction differs");
            assert_eq!(dec.array_disk_bytes, enc.array_disk_bytes);
            assert_eq!(dec.coded_streams, enc.coded_streams);
        }
    }

    #[test]
    fn identical_tables_are_interned_once() {
        let mut books = CodebookSet::new();
        let mut freq = vec![0u64; 4];
        freq[0] = 10;
        freq[1] = 5;
        freq[2] = 3;
        freq[3] = 1;
        let b1 = CodeBook::from_frequencies(&freq).unwrap();
        let b2 = CodeBook::from_frequencies(&freq).unwrap();
        assert!(books.marginal_bytes(&b1) > 0);
        let id1 = books.intern(b1);
        assert_eq!(books.marginal_bytes(&b2), 0);
        let id2 = books.intern(b2);
        assert_eq!(id1, id2);
        assert_eq!(books.len(), 1);
    }
}
