//! Per-operation time model — the paper's time criterion (§V: "we timed
//! each respective elementary operation and calculated the total time from
//! the sum of those values").
//!
//! Two sources of per-op latencies:
//!
//! * [`TimeModel::default_model`] — static values (ns) representative of a
//!   modern x86 core: ALU ops sub-nanosecond, loads tiered by working-set
//!   size (L1 / L2 / L3 / DRAM). Deterministic — used by all tables so
//!   EXPERIMENTS.md regenerates identically everywhere.
//! * [`TimeModel::calibrate`] — measures the host with simple timing
//!   kernels (pointer-chase-free streaming loads over arrays of each tier
//!   size, dependent add/mul chains). Enabled with `repro --calibrate-time`.
//!
//! Unlike the energy table, load latency on a real CPU is essentially
//! width-independent (an 8-bit and a 32-bit load cost the same); the model
//! therefore keys time only on op and tier. This divergence from the
//! paper's width-scaled energy model is deliberate and documented — it is
//! the reason the paper's own *time* gains (Table III middle rows) are much
//! smaller than its energy gains, a shape our model reproduces.

use std::time::Instant;

use super::energy::MemTier;
use super::opcount::BaseOp;
use crate::exec::ShardPlan;
use crate::formats::FormatKind;

/// Time model: ns per elementary operation.
#[derive(Clone, Debug)]
pub struct TimeModel {
    /// add latency (ns).
    pub add: f64,
    /// mul latency (ns).
    pub mul: f64,
    /// read/write by tier (ns).
    pub rw: [f64; 4],
    /// Per-dispatch pool overhead (ns) used by [`TimeModel::sharded_ns`].
    /// Defaults to the guessed [`TimeModel::DISPATCH_OVERHEAD_NS`];
    /// `repro calibrate` replaces it with a measured value.
    pub dispatch_overhead_ns: f64,
    /// Measured-vs-modeled wall-time ratio per format, indexed in
    /// [`FormatKind::ALL`] order (see [`TimeModel::scale_for`]). All 1.0
    /// (a bit-exact no-op on the time criterion) until calibration fits
    /// real slopes for the host.
    pub format_scale: [f64; FormatKind::COUNT],
}

impl TimeModel {
    /// Static defaults (ns), roughly: 4-wide issue ALU ops, L1 ≈ 1ns
    /// effective, L2 ≈ 2ns, L3 ≈ 6ns, DRAM ≈ 20ns streaming-amortized.
    pub fn default_model() -> TimeModel {
        TimeModel {
            add: 0.25,
            mul: 0.3,
            rw: [0.5, 2.0, 6.0, 20.0],
            dispatch_overhead_ns: Self::DISPATCH_OVERHEAD_NS,
            format_scale: [1.0; FormatKind::COUNT],
        }
    }

    /// Calibrated slope for `kind`: the factor the selector multiplies
    /// the trace-derived serial estimate by. Exactly 1.0 in the
    /// uncalibrated model, so default-model rankings are bit-identical to
    /// the historical ones.
    pub fn scale_for(&self, kind: FormatKind) -> f64 {
        let i = FormatKind::ALL
            .iter()
            .position(|&k| k == kind)
            .expect("FormatKind::ALL covers every kind");
        self.format_scale[i]
    }

    /// Cost in ns of one `op` on operands in tier `tier`.
    pub fn cost_ns(&self, op: BaseOp, _bits: u32, tier: MemTier) -> f64 {
        match op {
            BaseOp::Sum => self.add,
            BaseOp::Mul => self.mul,
            BaseOp::Read | BaseOp::Write => self.rw[tier as usize],
        }
    }

    /// Per-dispatch overhead (ns) of fanning a layer product across the
    /// exec pool: one condvar broadcast plus the shard joins. With the
    /// pipelined forward this is paid once per *forward*, but attributing
    /// it to each layer keeps single-layer estimates conservative.
    pub const DISPATCH_OVERHEAD_NS: f64 = 2_000.0;

    /// Predicted wall time of one layer product executed across `plan`'s
    /// shards, given the layer's serial estimate.
    ///
    /// This is the parallel arm of the cost model: the thread-aware
    /// format selector ([`crate::coordinator::select_format_in`]) scores
    /// every candidate format with it over the format's own plan, the
    /// harness reports the resulting per-layer winners at 1/2/4/8
    /// threads, and the dot bench records predicted-vs-measured times in
    /// `BENCH_dot.json`'s `selection` section.
    ///
    /// The parallel critical path is the *heaviest* shard, so the
    /// estimate scales by `plan.max_work() / plan.total_work()` — the
    /// actual nnz balance the planner achieved — rather than the ideal
    /// `1 / shards`. A perfectly balanced plan approaches the ideal; a
    /// plan dominated by one dense row predicts (correctly) almost no
    /// speed-up. Single-shard plans and zero-work layers return the
    /// serial estimate unchanged.
    ///
    /// ```
    /// use cer::costmodel::TimeModel;
    /// use cer::exec::ShardPlan;
    ///
    /// let tm = TimeModel::default_model();
    /// // 16 rows of equal work, 4 shards: near-ideal 4x speed-up.
    /// let balanced = ShardPlan::uniform(16, 100, 4);
    /// let par = tm.sharded_ns(1_000_000.0, &balanced);
    /// assert!(par < 300_000.0);
    /// // One row carries 900 of 930 work units: the critical path is that
    /// // row, so the same serial estimate barely speeds up at all.
    /// let skewed = ShardPlan::from_prefix(&[0, 900, 910, 920, 930], 4);
    /// assert!(tm.sharded_ns(1_000_000.0, &skewed) > 900_000.0);
    /// // Single-shard plans return the serial estimate unchanged.
    /// assert_eq!(tm.sharded_ns(1_000_000.0, &ShardPlan::uniform(16, 100, 1)), 1_000_000.0);
    /// ```
    pub fn sharded_ns(&self, serial_ns: f64, plan: &ShardPlan) -> f64 {
        let total = plan.total_work();
        if total == 0 || plan.shard_count() <= 1 {
            return serial_ns;
        }
        serial_ns * (plan.max_work() as f64 / total as f64) + self.dispatch_overhead_ns
    }

    /// Measure per-op latencies on the host. Best-effort (subject to
    /// frequency scaling etc.) — intended for the CLI's calibration flag,
    /// not for unit tests.
    pub fn calibrate() -> TimeModel {
        let add = time_dependent_chain(|a, b| a + b);
        let mul = time_dependent_chain(|a, b| a * b * 1.0000001 + 1e-30);
        let rw = [
            time_streaming_loads(4 * 1024),
            time_streaming_loads(24 * 1024),
            time_streaming_loads(512 * 1024),
            time_streaming_loads(8 * 1024 * 1024),
        ];
        TimeModel {
            add,
            mul,
            rw,
            ..TimeModel::default_model()
        }
    }
}

impl Default for TimeModel {
    fn default() -> Self {
        TimeModel::default_model()
    }
}

/// ns per op of a serially-dependent float chain.
fn time_dependent_chain(f: impl Fn(f32, f32) -> f32) -> f64 {
    const N: u64 = 2_000_000;
    let mut acc = 1.000001f32;
    let start = Instant::now();
    for i in 0..N {
        acc = f(acc, (i & 0xFF) as f32 * 1e-9 + 0.999999);
    }
    let ns = start.elapsed().as_nanos() as f64 / N as f64;
    std::hint::black_box(acc);
    ns
}

/// ns per element of a strided sweep over a working set of `bytes`.
fn time_streaming_loads(bytes: usize) -> f64 {
    let n = bytes / 4;
    let data: Vec<f32> = (0..n).map(|i| i as f32).collect();
    // Touch with a stride that defeats pure prefetch-friendliness a bit.
    let mut acc = 0.0f32;
    let reps: usize = (8 * 1024 * 1024 / bytes).max(1) * 4;
    let start = Instant::now();
    for r in 0..reps {
        let off = r % 7;
        let mut i = off;
        while i < n {
            acc += data[i];
            i += 16; // one element per cache line
        }
    }
    let touched = (reps * n.div_ceil(16)) as f64;
    let ns = start.elapsed().as_nanos() as f64 / touched;
    std::hint::black_box(acc);
    ns
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_model_is_monotone_in_tier() {
        let m = TimeModel::default_model();
        for w in 0..3 {
            assert!(m.rw[w] < m.rw[w + 1]);
        }
    }

    #[test]
    fn cost_lookup() {
        let m = TimeModel::default_model();
        assert_eq!(m.cost_ns(BaseOp::Sum, 32, MemTier::Under8K), 0.25);
        assert_eq!(m.cost_ns(BaseOp::Read, 8, MemTier::Over1M), 20.0);
        assert_eq!(m.cost_ns(BaseOp::Write, 32, MemTier::Under32K), 2.0);
    }

    #[test]
    fn sharded_estimate_follows_hand_computed_plan_balance() {
        let m = TimeModel::default_model();
        // Hand-computed skewed plan: row 0 carries 900 of 999 work units,
        // rows 1..=9 carry 11 each. At 4 shards the planner isolates the
        // heavy row, so max_work = 900 and the critical-path fraction is
        // 900/999 — nnz feedback, not the ideal 1/4.
        let mut prefix = vec![0u64, 900];
        for r in 1..10u64 {
            prefix.push(900 + r * 11);
        }
        let plan = ShardPlan::from_prefix(&prefix, 4);
        assert_eq!(plan.max_work(), 900);
        assert_eq!(plan.total_work(), 999);
        let serial = 999_000.0; // 1000 ns per work unit
        let got = m.sharded_ns(serial, &plan);
        let want = serial * (900.0 / 999.0) + TimeModel::DISPATCH_OVERHEAD_NS;
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        // A balanced uniform plan approaches the ideal 1/4 split.
        let even = ShardPlan::uniform(16, 100, 4);
        assert_eq!(even.max_work(), 400);
        let got = m.sharded_ns(serial, &even);
        let want = serial * 0.25 + TimeModel::DISPATCH_OVERHEAD_NS;
        assert!((got - want).abs() < 1e-9);
        // Degenerate plans fall back to the serial estimate.
        assert_eq!(m.sharded_ns(serial, &ShardPlan::uniform(8, 1, 1)), serial);
        assert_eq!(m.sharded_ns(serial, &ShardPlan::from_prefix(&[0, 0, 0], 2)), serial);
    }

    /// Satellite contract: when no calibration has been applied, the
    /// model must be bit-identical to the historical hard-coded one —
    /// same dispatch constant, unit format scales, same serial estimate
    /// at 1 thread.
    #[test]
    fn uncalibrated_model_is_bit_identical_to_historical_constants() {
        let m = TimeModel::default_model();
        assert_eq!(m.dispatch_overhead_ns, TimeModel::DISPATCH_OVERHEAD_NS);
        for kind in FormatKind::ALL {
            assert_eq!(m.scale_for(kind), 1.0);
        }
        // 1-thread (single-shard) estimates pass through untouched.
        let serial = 123_456.789f64;
        assert_eq!(m.sharded_ns(serial, &ShardPlan::uniform(64, 7, 1)), serial);
        // Multi-shard estimates reproduce the historical formula exactly.
        let plan = ShardPlan::uniform(16, 100, 4);
        let want = serial * (plan.max_work() as f64 / plan.total_work() as f64)
            + TimeModel::DISPATCH_OVERHEAD_NS;
        assert_eq!(m.sharded_ns(serial, &plan), want);
    }

    /// A calibrated overhead flows through `sharded_ns` in place of the
    /// hard-coded constant.
    #[test]
    fn calibrated_overhead_replaces_the_constant() {
        let mut m = TimeModel::default_model();
        m.dispatch_overhead_ns = 350.0;
        let plan = ShardPlan::uniform(16, 100, 4);
        let serial = 1_000_000.0;
        let want = serial * 0.25 + 350.0;
        assert!((m.sharded_ns(serial, &plan) - want).abs() < 1e-9);
        // Degenerate plans still bypass the overhead entirely.
        assert_eq!(m.sharded_ns(serial, &ShardPlan::uniform(8, 1, 1)), serial);
    }

    #[test]
    fn calibration_returns_positive_sane_values() {
        let m = TimeModel::calibrate();
        assert!(m.add > 0.0 && m.add < 100.0, "add {:?}", m.add);
        assert!(m.mul > 0.0 && m.mul < 100.0);
        for v in m.rw {
            assert!(v > 0.0 && v < 1000.0);
        }
    }
}
