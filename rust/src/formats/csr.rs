//! Compressed Sparse Row — the sparse baseline (§III-A "Sparse format").
//!
//! Stores the non-zero values in row-major order (`values`), their column
//! indices (`col_idx`) and row pointers into those arrays (`row_ptr`).

use super::storage::Storage;
use super::{ColIndices, Dense, IndexWidth, MatrixFormat, StorageBreakdown, StoragePart, VALUE_BITS};

/// CSR matrix with minimal-width column indices. All arrays are
/// [`Storage`]-backed: owned after conversion, zero-copy views into the
/// mapped pack after a `Pack::from_map` cold start (`row_ptr` is widened
/// into owned storage when its accounted on-disk width is narrower than
/// 32 bits — an O(rows) copy, never O(nnz)).
#[derive(Clone, Debug)]
pub struct Csr {
    rows: usize,
    cols: usize,
    /// Non-zero values in row-major scan order (the paper's `W`).
    pub values: Storage<f32>,
    /// Column index of each value.
    pub col_idx: ColIndices,
    /// `row_ptr[r]..row_ptr[r+1]` indexes `values`/`col_idx` for row `r`.
    pub row_ptr: Storage<u32>,
}

impl Csr {
    /// Row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Convert from dense, O(N).
    pub fn from_dense(m: &Dense) -> Csr {
        let (rows, cols) = (m.rows(), m.cols());
        let mut values = Vec::new();
        let mut cols_v: Vec<usize> = Vec::new();
        let mut row_ptr = Vec::with_capacity(rows + 1);
        row_ptr.push(0u32);
        for r in 0..rows {
            for (c, &v) in m.row(r).iter().enumerate() {
                if v != 0.0 {
                    values.push(v);
                    cols_v.push(c);
                }
            }
            row_ptr.push(values.len() as u32);
        }
        Csr {
            rows,
            cols,
            values: values.into(),
            col_idx: ColIndices::pack(&cols_v, cols),
            row_ptr: row_ptr.into(),
        }
    }

    /// Number of stored (non-zero) elements.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Accounted width of the row-pointer array (max value is nnz).
    pub fn row_ptr_width(&self) -> IndexWidth {
        IndexWidth::minimal(self.nnz())
    }

    /// `.cerpack` section codec. Header (`u32` rows, `u32` cols, `u64`
    /// nnz, width tags), then the arrays widest-first — `f32` values,
    /// rowPtr at its accounted width, colI at its accounted width — each
    /// padded to natural alignment. The array bytes equal the
    /// [`MatrixFormat::storage`] accounting exactly.
    pub fn encode_into(&self, out: &mut Vec<u8>) -> crate::pack::Emitted {
        use crate::pack::wire::{pad_rel, put_f32_array, put_u32, put_u32s_at_width, put_u64};
        let base = out.len();
        let rp_w = self.row_ptr_width();
        let ci_w = self.col_idx.width();
        put_u32(out, self.rows as u32);
        put_u32(out, self.cols as u32);
        put_u64(out, self.nnz() as u64);
        out.push(rp_w.tag());
        out.push(ci_w.tag());
        pad_rel(out, base, 4);
        let mut arrays = 0usize;
        let mark = out.len();
        put_f32_array(out, &self.values);
        arrays += out.len() - mark;
        pad_rel(out, base, rp_w.bytes());
        let mark = out.len();
        put_u32s_at_width(out, &self.row_ptr, rp_w);
        arrays += out.len() - mark;
        pad_rel(out, base, ci_w.bytes());
        let mark = out.len();
        self.col_idx.encode_into(out);
        arrays += out.len() - mark;
        crate::pack::Emitted {
            total: out.len() - base,
            arrays,
        }
    }

    /// Inverse of [`Csr::encode_into`]; `buf` must be exactly one payload.
    /// Decodes into owned storage.
    pub fn decode_from(buf: &[u8]) -> Result<Csr, crate::pack::PackError> {
        Csr::decode_from_source(buf, crate::pack::wire::ArrayLoader::owned())
    }

    /// [`Csr::decode_from`] with an explicit loader (zero-copy when
    /// mapped). Structure is validated (monotone rowPtr ending at nnz,
    /// in-range column indices) so corrupted input fails instead of
    /// mis-decoding.
    pub(crate) fn decode_from_source(
        buf: &[u8],
        src: crate::pack::wire::ArrayLoader<'_>,
    ) -> Result<Csr, crate::pack::PackError> {
        use crate::pack::wire::Cursor;
        use crate::pack::PackError;
        let mut cur = Cursor::new(buf);
        let rows = cur.u32_len("csr rows")?;
        let cols = cur.u32_len("csr cols")?;
        let nnz = cur.u64_len("csr nnz")?;
        if nnz > u32::MAX as usize || nnz as u64 > rows as u64 * cols as u64 {
            return Err(PackError::malformed("csr nnz out of range"));
        }
        let rp_w = IndexWidth::from_tag(cur.u8()?)
            .ok_or_else(|| PackError::malformed("bad rowPtr width tag"))?;
        let ci_w = IndexWidth::from_tag(cur.u8()?)
            .ok_or_else(|| PackError::malformed("bad colI width tag"))?;
        let rp_count = rows
            .checked_add(1)
            .ok_or_else(|| PackError::malformed("csr row count overflow"))?;
        cur.align(4)?;
        let values = src.typed::<f32>(&mut cur, nnz, "csr values")?;
        cur.align(rp_w.bytes())?;
        let row_ptr = src.u32s_at_width(&mut cur, rp_count, rp_w, "csr rowPtr")?;
        validate_row_ptr(&row_ptr, nnz, "csr")?;
        cur.align(ci_w.bytes())?;
        let col_idx = src.col_indices(&mut cur, ci_w, nnz, cols)?;
        if cur.remaining() != 0 {
            return Err(PackError::malformed("trailing bytes in csr payload"));
        }
        Ok(Csr {
            rows,
            cols,
            values,
            col_idx,
            row_ptr,
        })
    }
}

/// Shared pointer-array validation: starts at 0, non-decreasing, ends at
/// `last` — the invariant every decoded rowPtr/ΩPtr must satisfy.
pub(crate) fn validate_row_ptr(
    ptr: &[u32],
    last: usize,
    what: &str,
) -> Result<(), crate::pack::PackError> {
    use crate::pack::PackError;
    if ptr.first() != Some(&0) {
        return Err(PackError::malformed(format!("{what} pointer array must start at 0")));
    }
    if ptr.windows(2).any(|w| w[0] > w[1]) {
        return Err(PackError::malformed(format!("{what} pointer array not monotone")));
    }
    if *ptr.last().unwrap() as usize != last {
        return Err(PackError::malformed(format!(
            "{what} pointer array must end at {last}"
        )));
    }
    Ok(())
}

impl MatrixFormat for Csr {
    fn name(&self) -> &'static str {
        "CSR"
    }
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }

    fn to_dense(&self) -> Dense {
        let mut out = Dense::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (s, e) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            for i in s..e {
                out.set(r, self.col_idx.get(i), self.values[i]);
            }
        }
        out
    }

    fn storage(&self) -> StorageBreakdown {
        StorageBreakdown {
            parts: vec![
                StoragePart {
                    name: "Omega",
                    entries: self.values.len() as u64,
                    bits_per_entry: VALUE_BITS,
                },
                StoragePart {
                    name: "colI",
                    entries: self.col_idx.len() as u64,
                    bits_per_entry: self.col_idx.width().bits(),
                },
                StoragePart {
                    name: "rowPtr",
                    entries: self.row_ptr.len() as u64,
                    bits_per_entry: self.row_ptr_width().bits(),
                },
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::paper_example_matrix;

    #[test]
    fn paper_example_arrays() {
        // §III-A gives the exact CSR arrays of the 5×12 running example.
        let m = paper_example_matrix();
        let csr = Csr::from_dense(&m);
        assert_eq!(
            csr.values,
            vec![
                3., 2., 4., 2., 3., 4., 4., 4., 4., 4., 4., 4., 4., 4., 3., 4., 4., 2., 4., 4.,
                4., 3., 4., 4., 4., 4., 4., 4.
            ]
        );
        assert_eq!(
            csr.col_idx.to_vec(),
            vec![
                1, 3, 4, 7, 8, 9, 11, 0, 1, 5, 8, 9, 11, 0, 2, 3, 7, 9, 3, 4, 5, 7, 8, 9, 1, 2,
                5, 7
            ]
        );
        assert_eq!(csr.row_ptr, vec![0, 7, 13, 18, 24, 28]);
        // "62 entries" (§III-A): 28 values + 28 indices + 6 pointers.
        let entries: u64 = csr.storage().parts.iter().map(|p| p.entries).sum();
        assert_eq!(entries, 62);
    }

    #[test]
    fn roundtrip() {
        let m = paper_example_matrix();
        assert_eq!(Csr::from_dense(&m).to_dense(), m);
    }

    #[test]
    fn empty_and_full_rows() {
        let m = Dense::from_rows(&[
            vec![0.0, 0.0, 0.0],
            vec![1.0, 2.0, 3.0],
            vec![0.0, 5.0, 0.0],
        ]);
        let csr = Csr::from_dense(&m);
        assert_eq!(csr.nnz(), 4);
        assert_eq!(csr.row_ptr, vec![0, 0, 3, 4]);
        assert_eq!(csr.to_dense(), m);
    }

    #[test]
    fn all_zero_matrix() {
        let m = Dense::zeros(4, 7);
        let csr = Csr::from_dense(&m);
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.to_dense(), m);
    }

    #[test]
    fn storage_matches_eq3_shape() {
        // Eq. (3): per-element storage (1-p0)(b_Omega + b_I) + b_I/n (+ptr rounding).
        let m = paper_example_matrix();
        let csr = Csr::from_dense(&m);
        let bits = csr.storage().total_bits();
        // 28 values * 32 + 28 idx * 8 + 6 ptr * 8
        assert_eq!(bits, 28 * 32 + 28 * 8 + 6 * 8);
    }
}
