//! Fused-forward property suite: the pipelined forward pass (in-shard
//! bias+ReLU epilogue, one pool dispatch per forward, activation arena)
//! must be **bit-identical** — asserted with `assert_eq!`, never
//! tolerances — to the PR-2 unfused path (matmul, then a serial
//! `m × batch` bias+ReLU post-pass) across every format, every physical
//! index width (u8/u16/u32 columns), thread counts {1, 2, 4, 7} and batch
//! sizes {1, 3, 4, 8}; including the last-layer no-ReLU contract and an
//! all-negative-activation network.

use cer::coordinator::Engine;
use cer::formats::{Dense, FormatKind, IndexWidth};
use cer::kernels::AnyMatrix;
use cer::util::Rng;

const THREADS: [usize; 4] = [1, 2, 4, 7];
const BATCHES: [usize; 4] = [1, 3, 4, 8];

/// Random quantized layer. `implicit_zero` selects the Ω[0] regime: true →
/// zeros dominate (decomposed hot path), false → 5.0 dominates (the
/// Ω[0] ≠ 0 correction path in CER/CSER).
fn sample_matrix(rows: usize, cols: usize, implicit_zero: bool, rng: &mut Rng) -> Dense {
    let dominant = if implicit_zero { 0.0f32 } else { 5.0f32 };
    let rare = [1.0f32, -2.0, 0.25, 3.5, -0.75];
    let data: Vec<f32> = (0..rows * cols)
        .map(|_| {
            if rng.f32() < 0.6 {
                dominant
            } else {
                rare[rng.below(rare.len())]
            }
        })
        .collect();
    Dense::from_vec(rows, cols, data)
}

fn sample_bias(rows: usize, rng: &mut Rng) -> Vec<f32> {
    (0..rows).map(|_| rng.f32() * 2.0 - 1.0).collect()
}

/// The PR-2 unfused forward pass, reimplemented in-test from the public
/// kernel API (independent of `Engine::forward_reference`): per-layer
/// unfused matmul, then the serial bias+ReLU post-pass with the epilogue's
/// exact add order (`acc + bias[r]`, then clamp).
fn unfused_forward(
    layers: &[(String, Dense, Vec<f32>)],
    kind: FormatKind,
    x: &[f32],
    batch: usize,
) -> Vec<f32> {
    let last = layers.len() - 1;
    let mut cur: Vec<f32> = x.to_vec();
    for (i, (_, w, bias)) in layers.iter().enumerate() {
        let enc = AnyMatrix::encode(kind, w);
        let m = enc.rows();
        let mut out = vec![0.0f32; m * batch];
        enc.matmul_colmajor(&cur, &mut out, batch);
        for s in 0..batch {
            let col = &mut out[s * m..(s + 1) * m];
            for (v, b) in col.iter_mut().zip(bias) {
                *v += b;
                if i != last && *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        cur = out;
    }
    cur
}

fn assert_fused_matches(
    layers: &[(String, Dense, Vec<f32>)],
    rng: &mut Rng,
    label: &str,
) {
    let in_dim = layers[0].1.cols();
    for kind in FormatKind::ALL {
        for &batch in &BATCHES {
            let x: Vec<f32> = (0..batch * in_dim).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let want = unfused_forward(layers, kind, &x, batch);
            for &t in &THREADS {
                let mut e = Engine::native_fixed(layers.to_vec(), kind).with_threads(t);
                let got = e.forward(&x, batch).unwrap();
                assert_eq!(got, want, "{label}: {kind:?} batch={batch} t={t}");
                // Both paths on one engine agree too (reference path uses
                // the engine's own sharded drivers).
                assert_eq!(
                    e.forward_reference(&x, batch),
                    want,
                    "{label}: reference {kind:?} batch={batch} t={t}"
                );
                // Repeat on the warm arena: reuse must not drift.
                assert_eq!(
                    e.forward(&x, batch).unwrap(),
                    want,
                    "{label}: warm {kind:?} batch={batch} t={t}"
                );
            }
        }
    }
}

#[test]
fn fused_bit_identical_u8_indices_both_regimes() {
    let mut rng = Rng::new(0xF0_5E);
    for implicit_zero in [true, false] {
        let layers = vec![
            (
                "fc0".to_string(),
                sample_matrix(23, 37, implicit_zero, &mut rng),
                sample_bias(23, &mut rng),
            ),
            (
                "fc1".to_string(),
                sample_matrix(11, 23, implicit_zero, &mut rng),
                sample_bias(11, &mut rng),
            ),
            (
                "fc2".to_string(),
                sample_matrix(5, 11, implicit_zero, &mut rng),
                sample_bias(5, &mut rng),
            ),
        ];
        for (_, w, _) in &layers {
            if let AnyMatrix::Cer(c) = AnyMatrix::encode(FormatKind::Cer, w) {
                assert_eq!(c.col_idx.width(), IndexWidth::minimal(w.cols() - 1));
                assert_eq!(c.omega[0] != 0.0, !implicit_zero, "Ω[0] regime");
            }
        }
        assert_fused_matches(&layers, &mut rng, &format!("u8/iz={implicit_zero}"));
    }
}

#[test]
fn fused_bit_identical_u16_indices() {
    // 700 columns forces physically u16 column indices in the first layer.
    let mut rng = Rng::new(0xF16);
    let layers = vec![
        (
            "wide".to_string(),
            sample_matrix(9, 700, true, &mut rng),
            sample_bias(9, &mut rng),
        ),
        (
            "head".to_string(),
            sample_matrix(4, 9, true, &mut rng),
            sample_bias(4, &mut rng),
        ),
    ];
    if let AnyMatrix::Cser(c) = AnyMatrix::encode(FormatKind::Cser, &layers[0].1) {
        assert_eq!(c.col_idx.width(), IndexWidth::U16);
    }
    assert_fused_matches(&layers, &mut rng, "u16");
}

#[test]
fn fused_bit_identical_u32_indices() {
    // 70_000 columns forces u32 indices; keep rows tiny so the suite
    // stays fast. Fewer rows than threads also exercises lane idling.
    let mut rng = Rng::new(0xF32);
    let layers = vec![
        (
            "huge".to_string(),
            sample_matrix(3, 70_000, true, &mut rng),
            sample_bias(3, &mut rng),
        ),
        (
            "head".to_string(),
            sample_matrix(2, 3, true, &mut rng),
            sample_bias(2, &mut rng),
        ),
    ];
    if let AnyMatrix::Cer(c) = AnyMatrix::encode(FormatKind::Cer, &layers[0].1) {
        assert_eq!(c.col_idx.width(), IndexWidth::U32);
    }
    let in_dim = layers[0].1.cols();
    // Trim the matrix-product grid for this big shape: two batches.
    for kind in FormatKind::ALL {
        for batch in [1usize, 4] {
            let x: Vec<f32> = (0..batch * in_dim).map(|_| rng.f32() - 0.5).collect();
            let want = unfused_forward(&layers, kind, &x, batch);
            for &t in &THREADS {
                let mut e = Engine::native_fixed(layers.clone(), kind).with_threads(t);
                assert_eq!(e.forward(&x, batch).unwrap(), want, "{kind:?} b={batch} t={t}");
            }
        }
    }
}

#[test]
fn last_layer_logits_are_not_clamped() {
    // A network whose logits are all negative: the fused epilogue must
    // skip ReLU on the last layer exactly like the unfused post-pass.
    let w0 = Dense::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0]]);
    let w1 = Dense::from_rows(&[vec![-1.0, -1.0], vec![-2.0, 0.0], vec![0.0, -3.0]]);
    let layers = vec![
        ("id".to_string(), w0, vec![0.0, 0.0]),
        ("neg".to_string(), w1, vec![-0.5, -0.25, -0.125]),
    ];
    for kind in FormatKind::ALL {
        for &t in &THREADS {
            let mut e = Engine::native_fixed(layers.clone(), kind).with_threads(t);
            let y = e.forward(&[1.0, 2.0], 1).unwrap();
            assert_eq!(y, vec![-3.5, -2.25, -6.125], "{kind:?} t={t}");
            assert!(y.iter().all(|&v| v < 0.0), "logits must stay negative");
        }
    }
}

#[test]
fn all_negative_hidden_activations_zero_out() {
    // Every hidden pre-activation is negative → ReLU zeroes the entire
    // hidden layer → logits equal exactly the last layer's bias.
    let w0 = Dense::from_rows(&[vec![-1.0, -2.0, -1.0], vec![-3.0, -1.0, -2.0]]);
    let w1 = Dense::from_rows(&[vec![4.0, 5.0]]);
    let layers = vec![
        ("allneg".to_string(), w0, vec![-1.0, -2.0]),
        ("head".to_string(), w1, vec![0.75]),
    ];
    let x = vec![1.0f32, 2.0, 3.0]; // positive inputs, negative weights
    for kind in FormatKind::ALL {
        for &batch in &[1usize, 3] {
            let xs: Vec<f32> = x.iter().cycle().take(batch * 3).copied().collect();
            for &t in &THREADS {
                let mut e = Engine::native_fixed(layers.clone(), kind).with_threads(t);
                let y = e.forward(&xs, batch).unwrap();
                assert_eq!(y, vec![0.75f32; batch], "{kind:?} batch={batch} t={t}");
            }
        }
    }
}

#[test]
fn changing_batch_and_threads_on_one_engine_stays_exact() {
    // One long-lived engine (the serving scenario): interleave thread and
    // batch reconfiguration; every answer must stay bit-identical to the
    // freshly computed unfused reference.
    let mut rng = Rng::new(0xABCD);
    let layers = vec![
        (
            "fc0".to_string(),
            sample_matrix(31, 17, true, &mut rng),
            sample_bias(31, &mut rng),
        ),
        (
            "fc1".to_string(),
            sample_matrix(13, 31, false, &mut rng),
            sample_bias(13, &mut rng),
        ),
        (
            "fc2".to_string(),
            sample_matrix(6, 13, true, &mut rng),
            sample_bias(6, &mut rng),
        ),
    ];
    let mut e = Engine::native_fixed(layers.clone(), FormatKind::Cser);
    for (t, batch) in [(4usize, 8usize), (1, 1), (7, 3), (2, 8), (4, 1), (1, 4)] {
        e.set_threads(t);
        let x: Vec<f32> = (0..batch * 17).map(|_| rng.f32() - 0.5).collect();
        let want = unfused_forward(&layers, FormatKind::Cser, &x, batch);
        assert_eq!(e.forward(&x, batch).unwrap(), want, "t={t} batch={batch}");
    }
}
