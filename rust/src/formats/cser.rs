//! Compressed Shared Elements Row (CSER) — second contribution (§III-A).
//!
//! Relaxes CER's assumption that the frequency ordering is shared across
//! rows: an explicit per-run codebook-index array `ΩI` names the value of
//! each run, so rows with arbitrary per-row distributions encode without
//! padding. The most frequent element stays implicit (positions absent from
//! `colI`).

use std::collections::HashMap;

use super::codebook::{frequency_codebook, rank_lookup, value_key};
use super::storage::Storage;
use super::{ColIndices, Dense, IndexWidth, MatrixFormat, StorageBreakdown, StoragePart, VALUE_BITS};

/// CSER matrix. All arrays are [`Storage`]-backed — owned after
/// conversion, zero-copy views into the mapped pack after a
/// `Pack::from_map` cold start (pointer/ΩI arrays are widened into owned
/// storage when their accounted on-disk width is narrower than 32 bits).
#[derive(Clone, Debug)]
pub struct Cser {
    rows: usize,
    cols: usize,
    /// Distinct values. `omega[0]` is the implicit (most frequent) value;
    /// the rest are sorted ascending (the ordering is immaterial, §III-A —
    /// ascending keeps the representation canonical; the paper's example
    /// likewise lists Ω = [0, 2, 3, 4]).
    pub omega: Storage<f32>,
    /// Concatenated column-index runs.
    pub col_idx: ColIndices,
    /// Codebook index of each run (into `omega`, always ≥ 1).
    pub omega_idx: Storage<u32>,
    /// Run boundaries into `col_idx`; `omega_ptr[0] == 0`, length = runs+1.
    pub omega_ptr: Storage<u32>,
    /// `row_ptr[r]..row_ptr[r+1]` selects the run slots of row `r`.
    pub row_ptr: Storage<u32>,
}

impl Cser {
    /// Row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Convert from dense, O(N).
    ///
    /// Runs are emitted per row in *frequency-major* order (matching the
    /// paper's printed example, and making CSER's `colI` identical to
    /// CER's), while `ΩI` references the value-sorted codebook. §III-A
    /// notes both orderings are arbitrary as long as the arrays are
    /// mutually consistent.
    pub fn from_dense(m: &Dense) -> Cser {
        let codebook = frequency_codebook(m);
        let freq_ranks = rank_lookup(&codebook);
        // omega[0] = most frequent; the rest ascending by value.
        let mut omega: Vec<f32> = codebook.iter().map(|&(v, _)| v).collect();
        omega[1..].sort_by(|a, b| a.partial_cmp(b).expect("no NaN"));
        // frequency rank → index into `omega`, via a value-key map (a
        // linear scan per codebook entry would be O(K²) — measurable for
        // the K=2^12 quantization grids of the retrained pipelines).
        let omega_pos: HashMap<u32, u32> = omega
            .iter()
            .enumerate()
            .map(|(i, &v)| (value_key(v), i as u32))
            .collect();
        let mut rank_to_omega = vec![0u32; omega.len()];
        for (freq_rank, &(v, _)) in codebook.iter().enumerate() {
            rank_to_omega[freq_rank] = omega_pos[&value_key(v)];
        }

        let k = omega.len();
        let (rows, cols) = (m.rows(), m.cols());
        let mut col_idx: Vec<usize> = Vec::new();
        let mut omega_idx: Vec<u32> = Vec::new();
        let mut omega_ptr: Vec<u32> = vec![0];
        let mut row_ptr: Vec<u32> = vec![0];
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); k];
        for r in 0..rows {
            for b in buckets.iter_mut() {
                b.clear();
            }
            for (c, &v) in m.row(r).iter().enumerate() {
                let rank = freq_ranks[&value_key(v)] as usize;
                if rank != 0 {
                    buckets[rank].push(c);
                }
            }
            for (rank, bucket) in buckets.iter().enumerate().skip(1) {
                if !bucket.is_empty() {
                    col_idx.extend_from_slice(bucket);
                    omega_idx.push(rank_to_omega[rank]);
                    omega_ptr.push(col_idx.len() as u32);
                }
            }
            row_ptr.push((omega_ptr.len() - 1) as u32);
        }

        Cser {
            rows,
            cols,
            omega: omega.into(),
            col_idx: ColIndices::pack(&col_idx, cols),
            omega_idx: omega_idx.into(),
            omega_ptr: omega_ptr.into(),
            row_ptr: row_ptr.into(),
        }
    }

    /// Number of stored column indices.
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Number of distinct values (K).
    pub fn codebook_len(&self) -> usize {
        self.omega.len()
    }

    /// Total runs (Σ k̄_r — CSER has no padding).
    pub fn total_runs(&self) -> u64 {
        self.omega_idx.len() as u64
    }

    /// Average shared elements per row excluding the implicit value (k̄).
    pub fn kbar(&self) -> f64 {
        self.total_runs() as f64 / self.rows as f64
    }

    /// Accounted width of ΩPtr (values up to nnz).
    pub fn omega_ptr_width(&self) -> IndexWidth {
        IndexWidth::minimal(self.nnz())
    }

    /// Accounted width of rowPtr (values up to total_runs).
    pub fn row_ptr_width(&self) -> IndexWidth {
        IndexWidth::minimal(self.total_runs() as usize)
    }

    /// Accounted width of ΩI (values up to K-1).
    pub fn omega_idx_width(&self) -> IndexWidth {
        IndexWidth::minimal(self.codebook_len().saturating_sub(1))
    }

    /// Run-slot range of row `r`.
    #[inline]
    pub fn row_runs(&self, r: usize) -> (usize, usize) {
        (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize)
    }

    /// `.cerpack` section codec. Header (dims, K, counts, width tags),
    /// then the arrays widest-first — `f32` Ω, ΩPtr, rowPtr, ΩI, colI,
    /// pointer/index arrays at their accounted minimal widths, each
    /// padded to natural alignment. Array bytes equal
    /// [`MatrixFormat::storage`] exactly.
    pub fn encode_into(&self, out: &mut Vec<u8>) -> crate::pack::Emitted {
        use crate::pack::wire::{pad_rel, put_f32_array, put_u32, put_u32s_at_width, put_u64};
        let base = out.len();
        let op_w = self.omega_ptr_width();
        let rp_w = self.row_ptr_width();
        let oi_w = self.omega_idx_width();
        let ci_w = self.col_idx.width();
        put_u32(out, self.rows as u32);
        put_u32(out, self.cols as u32);
        put_u32(out, self.omega.len() as u32);
        put_u64(out, self.nnz() as u64);
        put_u64(out, self.total_runs());
        out.push(op_w.tag());
        out.push(rp_w.tag());
        out.push(oi_w.tag());
        out.push(ci_w.tag());
        pad_rel(out, base, 4);
        let mut arrays = 0usize;
        let mark = out.len();
        put_f32_array(out, &self.omega);
        arrays += out.len() - mark;
        pad_rel(out, base, op_w.bytes());
        let mark = out.len();
        put_u32s_at_width(out, &self.omega_ptr, op_w);
        arrays += out.len() - mark;
        pad_rel(out, base, rp_w.bytes());
        let mark = out.len();
        put_u32s_at_width(out, &self.row_ptr, rp_w);
        arrays += out.len() - mark;
        pad_rel(out, base, oi_w.bytes());
        let mark = out.len();
        put_u32s_at_width(out, &self.omega_idx, oi_w);
        arrays += out.len() - mark;
        pad_rel(out, base, ci_w.bytes());
        let mark = out.len();
        self.col_idx.encode_into(out);
        arrays += out.len() - mark;
        crate::pack::Emitted {
            total: out.len() - base,
            arrays,
        }
    }

    /// Inverse of [`Cser::encode_into`]; `buf` must be exactly one
    /// payload. Decodes into owned storage.
    pub fn decode_from(buf: &[u8]) -> Result<Cser, crate::pack::PackError> {
        Cser::decode_from_source(buf, crate::pack::wire::ArrayLoader::owned())
    }

    /// [`Cser::decode_from`] with an explicit loader (zero-copy when
    /// mapped). Validates run structure and that every ΩI entry names a
    /// non-implicit codebook value.
    pub(crate) fn decode_from_source(
        buf: &[u8],
        src: crate::pack::wire::ArrayLoader<'_>,
    ) -> Result<Cser, crate::pack::PackError> {
        use crate::formats::csr::validate_row_ptr;
        use crate::pack::wire::Cursor;
        use crate::pack::PackError;
        let mut cur = Cursor::new(buf);
        let rows = cur.u32_len("cser rows")?;
        let cols = cur.u32_len("cser cols")?;
        let k = cur.u32_len("cser codebook size")?;
        let nnz = cur.u64_len("cser nnz")?;
        let total_runs = cur.u64_len("cser run count")?;
        if nnz > u32::MAX as usize || nnz as u64 > rows as u64 * cols as u64 {
            return Err(PackError::malformed("cser nnz out of range"));
        }
        if total_runs > u32::MAX as usize {
            return Err(PackError::malformed("cser run count out of range"));
        }
        // u64 arithmetic: rows/cols are u32-sized but their product (and
        // rows + 1 on 32-bit hosts) could overflow usize.
        if k == 0 && rows as u64 * cols as u64 != 0 {
            return Err(PackError::malformed("cser empty codebook for non-empty matrix"));
        }
        let rp_count = rows
            .checked_add(1)
            .ok_or_else(|| PackError::malformed("cser row count overflow"))?;
        let op_count = total_runs
            .checked_add(1)
            .ok_or_else(|| PackError::malformed("cser run count overflow"))?;
        let op_w = IndexWidth::from_tag(cur.u8()?)
            .ok_or_else(|| PackError::malformed("bad OmegaPtr width tag"))?;
        let rp_w = IndexWidth::from_tag(cur.u8()?)
            .ok_or_else(|| PackError::malformed("bad rowPtr width tag"))?;
        let oi_w = IndexWidth::from_tag(cur.u8()?)
            .ok_or_else(|| PackError::malformed("bad OmegaI width tag"))?;
        let ci_w = IndexWidth::from_tag(cur.u8()?)
            .ok_or_else(|| PackError::malformed("bad colI width tag"))?;
        cur.align(4)?;
        let omega = src.typed::<f32>(&mut cur, k, "cser codebook")?;
        cur.align(op_w.bytes())?;
        let omega_ptr = src.u32s_at_width(&mut cur, op_count, op_w, "cser OmegaPtr")?;
        validate_row_ptr(&omega_ptr, nnz, "cser Omega")?;
        cur.align(rp_w.bytes())?;
        let row_ptr = src.u32s_at_width(&mut cur, rp_count, rp_w, "cser rowPtr")?;
        validate_row_ptr(&row_ptr, total_runs, "cser row")?;
        cur.align(oi_w.bytes())?;
        let omega_idx = src.u32s_at_width(&mut cur, total_runs, oi_w, "cser OmegaI")?;
        if omega_idx.iter().any(|&i| i == 0 || i as usize >= k) {
            return Err(PackError::malformed("cser OmegaI entry out of range"));
        }
        cur.align(ci_w.bytes())?;
        let col_idx = src.col_indices(&mut cur, ci_w, nnz, cols)?;
        if cur.remaining() != 0 {
            return Err(PackError::malformed("trailing bytes in cser payload"));
        }
        Ok(Cser {
            rows,
            cols,
            omega,
            col_idx,
            omega_idx,
            omega_ptr,
            row_ptr,
        })
    }
}

impl MatrixFormat for Cser {
    fn name(&self) -> &'static str {
        "CSER"
    }
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }

    fn to_dense(&self) -> Dense {
        let mut out = Dense::zeros(self.rows, self.cols);
        let w0 = self.omega[0];
        if w0 != 0.0 {
            out.data_mut().fill(w0);
        }
        for r in 0..self.rows {
            let (s, e) = self.row_runs(r);
            for slot in s..e {
                let value = self.omega[self.omega_idx[slot] as usize];
                let (rs, re) = (
                    self.omega_ptr[slot] as usize,
                    self.omega_ptr[slot + 1] as usize,
                );
                for i in rs..re {
                    out.set(r, self.col_idx.get(i), value);
                }
            }
        }
        out
    }

    fn storage(&self) -> StorageBreakdown {
        StorageBreakdown {
            parts: vec![
                StoragePart {
                    name: "Omega",
                    entries: self.omega.len() as u64,
                    bits_per_entry: VALUE_BITS,
                },
                StoragePart {
                    name: "colI",
                    entries: self.col_idx.len() as u64,
                    bits_per_entry: self.col_idx.width().bits(),
                },
                StoragePart {
                    name: "OmegaI",
                    entries: self.omega_idx.len() as u64,
                    bits_per_entry: self.omega_idx_width().bits(),
                },
                StoragePart {
                    name: "OmegaPtr",
                    entries: self.omega_ptr.len() as u64,
                    bits_per_entry: self.omega_ptr_width().bits(),
                },
                StoragePart {
                    name: "rowPtr",
                    entries: self.row_ptr.len() as u64,
                    bits_per_entry: self.row_ptr_width().bits(),
                },
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::paper_example_matrix;

    #[test]
    fn paper_example_arrays() {
        // §III-A gives the exact CSER arrays of the 5×12 running example.
        let cser = Cser::from_dense(&paper_example_matrix());
        assert_eq!(cser.omega, vec![0.0, 2.0, 3.0, 4.0]);
        assert_eq!(
            cser.col_idx.to_vec(),
            vec![
                4, 9, 11, 1, 8, 3, 7, 0, 1, 5, 8, 9, 11, 0, 3, 7, 2, 9, 3, 4, 5, 8, 9, 7, 1, 2,
                5, 7
            ]
        );
        assert_eq!(cser.omega_idx, vec![3, 2, 1, 3, 3, 2, 1, 3, 2, 3]);
        assert_eq!(cser.omega_ptr, vec![0, 3, 5, 7, 13, 16, 17, 18, 23, 24, 28]);
        assert_eq!(cser.row_ptr, vec![0, 3, 4, 7, 9, 10]);
        // "59 entries" (§III-A): 4 + 28 + 10 + 11 + 6.
        let entries: u64 = cser.storage().parts.iter().map(|p| p.entries).sum();
        assert_eq!(entries, 59);
    }

    #[test]
    fn roundtrip_paper_example() {
        let m = paper_example_matrix();
        assert_eq!(Cser::from_dense(&m).to_dense(), m);
    }

    #[test]
    fn row_local_distributions_no_padding() {
        // A matrix whose per-row frequency orderings differ wildly — CER
        // pays padding, CSER does not.
        let m = Dense::from_rows(&[
            vec![0.0, 1.0, 1.0, 2.0],
            vec![0.0, 2.0, 2.0, 1.0],
            vec![0.0, 3.0, 3.0, 3.0],
        ]);
        let cser = Cser::from_dense(&m);
        let cer = super::super::Cer::from_dense(&m);
        assert_eq!(cser.to_dense(), m);
        assert_eq!(cer.to_dense(), m);
        assert_eq!(cser.total_runs(), 5); // 2+2+1 non-empty runs
        assert!(cer.padded_runs() > 0); // CER must pad the gap rows
    }

    #[test]
    fn all_zero_matrix() {
        let m = Dense::zeros(3, 5);
        let cser = Cser::from_dense(&m);
        assert_eq!(cser.nnz(), 0);
        assert_eq!(cser.to_dense(), m);
    }

    #[test]
    fn implicit_value_not_zero() {
        let m = Dense::from_rows(&[vec![9.0, 9.0, 1.0], vec![9.0, 9.0, 0.0]]);
        let cser = Cser::from_dense(&m);
        assert_eq!(cser.omega[0], 9.0);
        assert_eq!(cser.to_dense(), m);
    }

    #[test]
    fn kbar_matches_distinct_count() {
        let m = paper_example_matrix();
        let cser = Cser::from_dense(&m);
        // rows have 3,1,3,2,1 distinct non-zero values → k̄ = 10/5 = 2.
        assert!((cser.kbar() - 2.0).abs() < 1e-12);
    }
}
