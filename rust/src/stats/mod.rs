//! Statistical machinery of the paper's experiments:
//!
//! * [`entropy`] — Shannon entropy of pmfs and matrices, feasibility limits
//!   of the (H, p₀) plane (the two black boundary lines of Figs. 3/10).
//! * [`synth`] — the (H, p₀)-plane matrix synthesizer behind Figs. 4 & 5:
//!   builds a pmf with exactly the requested entropy/sparsity and samples
//!   iid matrices from it.
//! * [`quantize`] — the uniform quantizer of §V-B.
//! * [`decompose`] — the Appendix A.1 preprocessing `W = Ŵ + ω_max·𝟙`.

pub mod decompose;
pub mod entropy;
pub mod quantize;
pub mod synth;

pub use decompose::Decomposed;
pub use entropy::{entropy_bits, matrix_entropy, max_entropy, min_entropy};
pub use quantize::UniformQuantizer;
pub use synth::{spike_and_slab, PlanePoint};
