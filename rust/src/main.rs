//! `repro` — the reproduction launcher.
//!
//! One subcommand per paper table/figure (DESIGN.md §3 experiment index),
//! plus the e2e driver and the demo server. Run `repro help` for usage.
//!
//! Argument parsing is hand-rolled (clap is not in the offline vendor set —
//! DESIGN.md §4); flags are `--key value` pairs after the subcommand.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use cer::costmodel::{EnergyModel, TimeModel};
use cer::harness::{figures, tables};
use cer::harness::eval::{EvalConfig, NetworkEval};
use cer::networks::weights::TargetStats;
use cer::networks::zoo::NetworkSpec;

struct Args {
    flags: HashMap<String, String>,
    /// Bare (non `--flag`) arguments, e.g. the file path of
    /// `repro inspect net.cerpack`.
    positional: Vec<String>,
}

impl Args {
    fn parse(rest: &[String]) -> Result<Args, String> {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < rest.len() {
            let Some(key) = rest[i].strip_prefix("--") else {
                positional.push(rest[i].clone());
                i += 1;
                continue;
            };
            let value = if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                i += 1;
                rest[i].clone()
            } else {
                "true".to_string()
            };
            flags.insert(key.to_string(), value);
            i += 1;
        }
        Ok(Args { flags, positional })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

/// The engine-configuration vocabulary shared by
/// pack/e2e/serve/serve-net/calibrate (and, through [`eval_config`], the
/// table/figure commands): `--threads`, `--kernel`, `--objective`, and
/// `--calibration`, parsed **once** and identically everywhere. Each
/// command reads the fields it cares about; there is exactly one place
/// the flag spellings, env-var fallbacks, and error messages live.
struct CommonOpts {
    /// Resolved exec-plane lane count (`--threads`, `auto`/`0` = all
    /// cores, fallback `CER_THREADS`, else 1).
    threads: usize,
    /// Whether `--threads` (or the env var) was an explicit request —
    /// replan only forwards the field when the user asked.
    threads_requested: Option<usize>,
    /// `--kernel scalar|simd|auto` (fallback `CER_KERNEL`, else scalar).
    kernel: cer::kernels::KernelBackend,
    /// `--objective energy|time|ops|storage` (default energy).
    objective: cer::coordinator::Objective,
    /// The objective's flag spelling, for log lines and JSON bodies.
    objective_str: String,
    /// Whether `--objective` was given explicitly (replan omits the
    /// field otherwise, so the server keeps its default).
    objective_requested: bool,
    /// Parsed `--calibration FILE` constants, when the flag was given.
    calibration: Option<cer::costmodel::Calibration>,
    /// The `--calibration` path, for log lines.
    calibration_path: String,
}

impl CommonOpts {
    fn parse(a: &Args) -> anyhow::Result<CommonOpts> {
        use cer::coordinator::Objective;
        use cer::kernels::KernelBackend;

        let threads_requested = threads_flag(a);
        let threads = cer::exec::resolve_threads(threads_requested);
        let kernel = match a.flags.get("kernel") {
            Some(v) => KernelBackend::parse(v).map_err(|e| anyhow::anyhow!("--kernel: {e}"))?,
            None => KernelBackend::from_env().map_err(|e| anyhow::anyhow!(e))?,
        };
        let objective_str = a.get_str("objective", "energy");
        let objective = match objective_str.as_str() {
            "energy" => Objective::Energy,
            "time" => Objective::Time,
            "ops" => Objective::Ops,
            "storage" => Objective::Storage,
            other => anyhow::bail!("unknown objective '{other}' (energy|time|ops|storage)"),
        };
        let calibration_path = a.get_str("calibration", "");
        let calibration = if calibration_path.is_empty() {
            None
        } else {
            let text = std::fs::read_to_string(&calibration_path)
                .map_err(|e| anyhow::anyhow!("reading {calibration_path}: {e}"))?;
            Some(
                cer::costmodel::Calibration::parse_str(&text)
                    .map_err(|e| anyhow::anyhow!("parsing {calibration_path}: {e}"))?,
            )
        };
        Ok(CommonOpts {
            threads,
            threads_requested,
            kernel,
            objective,
            objective_str,
            objective_requested: a.has("objective"),
            calibration,
            calibration_path,
        })
    }

    /// `calibrate`'s spelling of `--kernel`: a single backend, or `all`
    /// (the default) for every backend this host supports. Lives here so
    /// the single-backend arm shares [`CommonOpts::parse`]'s vocabulary.
    fn backends_flag(a: &Args) -> anyhow::Result<Vec<cer::kernels::KernelBackend>> {
        use cer::kernels::KernelBackend;
        let spec = a.get_str("kernel", "all");
        if spec == "all" {
            let mut b = vec![KernelBackend::Scalar];
            if KernelBackend::simd_supported() {
                b.push(KernelBackend::Simd);
            }
            return Ok(b);
        }
        Ok(vec![
            KernelBackend::parse(&spec).map_err(|e| anyhow::anyhow!("--kernel: {e}"))?,
        ])
    }
}

fn eval_config(a: &Args) -> anyhow::Result<EvalConfig> {
    let co = CommonOpts::parse(a)?;
    let mut cfg = EvalConfig {
        seed: a.get("seed", 0xCE5Eu64),
        scale: a.get("scale", 1usize),
        wallclock: !a.has("no-wallclock"),
        disk: false, // the table2/alexnet/all arms opt in
        energy: EnergyModel::table_i(),
        time: TimeModel::default_model(),
    };
    if a.has("calibrate-time") {
        eprintln!("calibrating per-op time model on this host ...");
        cfg.time = TimeModel::calibrate();
        eprintln!(
            "  add {:.3}ns mul {:.3}ns rw {:?}ns",
            cfg.time.add, cfg.time.mul, cfg.time.rw
        );
    }
    if let Some(cal) = &co.calibration {
        // The fit for the backend the engines will actually run (see
        // --kernel); absent fits leave the analytic scales at 1.0.
        cfg.time = cal.apply(&cfg.time, co.kernel);
        eprintln!(
            "applied {} ({} fit): format scales {:?}, dispatch {:.0} ns",
            co.calibration_path, co.kernel, cfg.time.format_scale, cfg.time.dispatch_overhead_ns
        );
    }
    Ok(cfg)
}

fn out_dir(a: &Args) -> PathBuf {
    PathBuf::from(a.get_str("out", "results"))
}

const HELP: &str = "\
repro — reproduction harness for 'Compact and Computationally Efficient
Representation of Deep Neural Networks' (Wiedemann, Müller & Samek, 2018)

USAGE: repro <command> [--flag value ...]

Experiment commands (DESIGN.md §3; CSVs land in --out, default results/):
  table1                     print the Table I energy constants
  table2                     storage gains, §V-B nets (Table II)
  table3                     #ops/time/energy gains, §V-B nets (Table III)
  table4                     effective network statistics (Table IV)
  table5                     storage gains, retrained nets (Table V)
  table6                     #ops/time/energy gains, retrained nets (Table VI)
  alexnet                    AlexNet Deep-Compression gains (Fig. 11/14)
  packed-dense               7-bit packed-dense decode penalty (§V-B note)
  figure1                    quantized VGG-16 fc8 distribution (Fig. 1)
  figure4                    (H,p0)-plane winner map (Fig. 4)
  figure5                    column-size scaling (Fig. 5)
  figure10                   per-layer (H,p0) scatter (Fig. 10)
  breakdown --net <name>     storage/ops/time/energy breakdowns (Figs. 6-9, 12-13)
  all                        run every experiment above

Artifact commands (.cerpack — the on-disk format for compressed networks):
  pack --network <name>      compress a zoo network (synthesize → auto-select
                             among dense/csr/cer/cser/bsr/tnn per layer) and
                             serialize it to --out (default
                             <name>.cerpack); add --objective
                             energy|time|ops|storage (default energy),
                             --scale N for shrunken quick runs. Selection is
                             thread-aware: with --threads N the time
                             criterion is each format's sharded critical
                             path at N lanes, so the packed formats can
                             differ between --threads 1 and --threads 8.
                             Besides the zoo, three diagnostic nets pin
                             selector flips: spike-slab (csr at 1 thread,
                             dense at 8), block-structured (csr -> bsr on
                             time), ternary (cser -> tnn on storage).
                             --entropy adds the Huffman-coded storage
                             tier: integer index/codebook arrays are
                             entropy-coded per section (streamed, bounded
                             peak memory), each stream kept only when it
                             pays for itself including its code-book
                             share; readers decode once at load
  inspect <file.cerpack>     verify checksums, dump header + manifest, and
                             compare measured on-disk bytes per layer with
                             the analytic StorageBreakdown bits and the
                             N*H entropy bound (divergence >5% is flagged);
                             then cold-start an engine from the file.
                             On entropy-coded packs a `coded` column and
                             totals line report the coded tier.
                             --assert-coded exits non-zero unless the
                             pack is coded and coded on-disk bytes <= raw
                             array bytes; --assert-coded-within P exits
                             non-zero when coded bytes exceed the N*H
                             bound by more than P percent (a regression
                             tripwire — index-carrying formats sit above
                             N*H by construction, so give it headroom)
  pack-demo                  tiny end-to-end demo: pack the paper's 5x12
                             example matrix, reload, run a dot product

System commands:
  e2e                        end-to-end inference over the AOT artifacts
                             (XLA backends skip gracefully when the crate
                             is built without the `xla` feature)
  serve                      demo inference server (dynamic batching +
                             metrics); --threads N fans every batch —
                             including 1–3 sample remainders — across the
                             exec pool's fused forward pipeline
  serve <a.cerpack> [b...]   serve packed networks through the zero-copy
                             mmap cold start: each pack is mapped once and
                             --workers N engines share that one mapping
                             (requests round-robin across workers; multiple
                             packs are routed per request by file stem);
                             --verify checks every reply bit-for-bit
                             against the owned-storage reader
  serve-net <a.cerpack> ...  network front end over the same worker plane:
                             HTTP/1.1 on --addr (default 127.0.0.1:8080;
                             port 0 = ephemeral, --port-file FILE writes
                             the bound address). POST /v1/infer with
                             {\"input\":[...],\"pack\":...,\"deadline_ms\":...},
                             GET /healthz, GET /metrics (p50/p99/p999 +
                             steal/replan/imbalance gauges),
                             POST /admin/{reload,replan,drain,shutdown}. Bounded
                             admission: --max-inflight N full => 429 +
                             Retry-After; expired --deadline-ms => 504
                             before a worker is touched; SIGTERM stops
                             accepting, finishes in-flight work, exits 0
  loadgen                    drive a running serve-net and emit
                             BENCH_serve.json: closed-loop --concurrency
                             list and open-loop Poisson --rates list
                             (coordinated-omission-free latency), each
                             step --duration-ms; reports throughput,
                             p50/p99/p999, and the knee point. --trace
                             FILE replays recorded arrival offsets (one
                             per line, seconds; # comments) instead of
                             the synthetic sweeps, still open-loop.
                             --smoke self-hosts a loopback server and
                             asserts replies bit-identical to the
                             in-process path; --verify-pack <f.cerpack>
                             does the same against a live server
  reload <name> <f.cerpack>  hot-swap the pack behind a serve-net route
                             (--addr): atomic under traffic, in-flight
                             requests finish on the old weights
  replan                     live re-planning on a running serve-net
                             (--addr): --threads N reconfigures each
                             worker's exec plane, --calibrate re-fits the
                             time model on the quiesced worker, then
                             formats are re-selected (--objective,
                             default time) per layer. --name R picks one
                             route (default all); --expect-flip exits
                             non-zero when no layer changed format.
                             Weights and generations are untouched
  bench-gate                 diff --fresh BENCH_*.json against a committed
                             --baseline; exits non-zero when any tracked
                             metric (…_ms/…_ns/…_us lower-better; gflops,
                             speedups, compression_ratio, throughput_rps
                             higher-better) regresses more than
                             --max-regress-pct (default 25); an empty
                             baseline prints SEEDING (no baseline) per
                             metric and exits 2 (gating inert) so CI logs
                             can't mistake it for a pass; --update
                             rewrites the baseline
  calibrate                  micro-benchmark the matvec kernels (cache-
                             ruined, best-of-N) and fit measured
                             per-(format, backend) time-model scales +
                             intercepts and the pool dispatch overhead;
                             writes --out (default calibration.json,
                             consumed via --calibration) and --bench-out
                             (default BENCH_calibration.json, tracked by
                             the CI bench gate). --smoke shrinks sizes
                             for CI; --kernel scalar|simd|auto|all picks
                             the backends to fit (default all supported)
  inspect --net <name>       print layer statistics of a synthesized net
  help                       this text

Exit codes: 0 = success; 1 = any error (bad flags, bind/pack failure,
bench regression), reported as one line on stderr; 2 = bench-gate ran
against an empty baseline (seeding — gating inert).

Common flags:
  --seed N          RNG seed (default 0xCE5E)
  --scale N         divide layer dims by N for quick runs (default 1 = paper-exact)
  --out DIR|FILE    CSV output directory (default results/); for `pack`, the
                    output .cerpack path
  --no-wallclock    skip real-kernel wall-clock measurement
  --calibrate-time  measure per-op latencies on this host instead of defaults
  --artifacts DIR   artifacts directory for e2e/serve (default artifacts/)
  --threads N       kernel execution threads for pack/e2e/serve engines
                    (0 = all cores; default: CER_THREADS env, else 1 =
                    serial). Parallel output is bit-identical to serial —
                    rows are sharded by stored-index count per layer, the
                    bias+ReLU epilogue is fused into each shard, and one
                    forward pass costs one pool dispatch. Format
                    auto-selection evaluates the time criterion at this
                    count (see docs/ARCHITECTURE.md).
  --objective O     deployment argmin for pack/e2e/serve format selection:
                    energy|time|ops|storage (default energy); `time`
                    interacts with --threads
  --workers N       server engines per pack for `serve <pack>` (default 1);
                    all N share one mapped copy of the weights
  --requests N      demo request count for the serve commands
  --verify          (serve <pack>) assert every reply equals the
                    owned-storage cold-start path bit-for-bit
  --prefault        (serve <pack>) madvise(WILLNEED) the mapped pack up
                    front so first-request latency doesn't pay the page
                    faults (also via PackOptions::prefault in the API)
  --kernel K        inner-loop implementation for e2e/serve engines:
                    scalar (default — frozen reduction order, the repo's
                    bit-exactness reference), simd (AVX2/SSE2 on x86_64,
                    NEON on aarch64; reassociated sums, tolerance-tested,
                    never implicit), auto (simd when the target has
                    vector kernels). Falls back to the CER_KERNEL env
                    var, then scalar. `serve --verify` forces scalar
  --calibration F   apply fitted time-model constants from a
                    `repro calibrate` output file to modeled tables and
                    format selection (the fit for the --kernel backend)
";

/// `--threads` as an explicit request: a number, or `auto`/`0` for all
/// cores. Absent or unparsable values fall back to `CER_THREADS` (None).
fn threads_flag(a: &Args) -> Option<usize> {
    let v = a.flags.get("threads")?;
    if v.eq_ignore_ascii_case("auto") {
        Some(0)
    } else {
        v.parse().ok()
    }
}

/// Exit protocol: 0 = success, 1 = any error (bad flags, bind failure,
/// missing pack, regression), 2 = bench-gate ran in seeding mode (no
/// baseline — gating inert). Every subcommand error funnels through the
/// single `Err` arm here: one line on stderr, nonzero exit, no panics.
fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let args = match Args::parse(&argv[1.min(argv.len())..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("repro: {e}\n\n{HELP}");
            return ExitCode::FAILURE;
        }
    };
    match run(cmd, &args) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("repro {cmd}: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(cmd: &str, a: &Args) -> anyhow::Result<ExitCode> {
    // Only `inspect` (the .cerpack path), `serve`/`serve-net` (packs to
    // serve), and `reload` (route name + pack) take bare arguments;
    // anywhere else a stray positional is a mistyped flag — fail loudly
    // rather than silently running with defaults.
    if !a.positional.is_empty() && !matches!(cmd, "inspect" | "serve" | "serve-net" | "reload") {
        anyhow::bail!(
            "unexpected argument '{}' — flags are `--key value` (run `repro help`)",
            a.positional[0]
        );
    }
    match cmd {
        "help" | "--help" | "-h" => print!("{HELP}"),
        "table1" => print!("{}", tables::table1()),
        "table2" | "table3" | "table4" => {
            let mut cfg = eval_config(a)?;
            // Only table2 prints the measured disk columns.
            cfg.disk = cmd == "table2";
            eprintln!(
                "evaluating VGG16 / ResNet152 / DenseNet at scale {} (seed {}) ...",
                cfg.scale, cfg.seed
            );
            let evals = tables::eval_vb_networks(&cfg);
            let dir = out_dir(a);
            match cmd {
                "table2" => print!("{}", tables::table2(&evals, Some(&dir))?),
                "table3" => print!("{}", tables::table3(&evals, Some(&dir))?),
                _ => print!("{}", tables::table4(&evals, Some(&dir))?),
            }
        }
        "table5" | "table6" => {
            let cfg = eval_config(a)?;
            eprintln!("running §V-C compression pipelines (scale {}) ...", cfg.scale);
            let evals = tables::eval_retrained_networks(&cfg);
            let dir = out_dir(a);
            if cmd == "table5" {
                print!("{}", tables::table5(&evals, Some(&dir))?);
            } else {
                print!("{}", tables::table6(&evals, Some(&dir))?);
            }
        }
        "alexnet" => {
            let mut cfg = eval_config(a)?;
            cfg.disk = true; // the storage table below reports disk columns
            eprintln!("running Deep-Compression AlexNet pipeline ...");
            let ev = tables::eval_alexnet_dc(&cfg);
            let dir = out_dir(a);
            print!("{}", tables::table2(std::slice::from_ref(&ev), None)?);
            print!(
                "{}",
                tables::table_ops_time_energy(
                    std::slice::from_ref(&ev),
                    (1e9, "G"),
                    (1e9, "s"),
                    (1e12, "J"),
                    "alexnet.csv",
                    Some(&dir),
                )?
            );
            let (p0, h, kbar, n) = ev.effective_stats();
            println!("stats: p0 {p0:.2}  H {h:.2}  kbar {kbar:.2}  n {n:.2}");
        }
        "packed-dense" => {
            let cfg = eval_config(a)?;
            let (modeled, wall) = tables::packed_dense_experiment(&cfg);
            println!("packed-dense vs dense matvec (VGG16-shaped, 7-bit codes):");
            println!("  modeled time delta:   {modeled:+.1}%");
            println!("  wallclock time delta: {wall:+.1}%  (paper: ≈ +47%)");
            let (plain, packed) = tables::csr_decode_overhead(&cfg);
            println!(
                "CSR with coded values (decode per nnz): {:+.1}% modeled time vs plain CSR",
                (packed / plain - 1.0) * 100.0
            );
        }
        "figure1" => {
            let (mode, freq, k) = figures::figure1(&out_dir(a), a.get("seed", 1u64))?;
            println!(
                "VGG-16 fc8 quantized: K = {k}, most frequent value {mode:.4} at {:.2}% \
                 (paper: -0.008 at ≈4.2%)",
                freq * 100.0
            );
            println!("CSVs: figure1_pmf.csv, figure1_top15.csv");
        }
        "figure4" => {
            let cfg = eval_config(a)?;
            let grid = a.get("grid", 24usize);
            let samples = a.get("samples", 10usize);
            let (m, n) = (a.get("rows", 100usize), a.get("cols", 100usize));
            let k = a.get("k", 128usize);
            eprintln!("sweeping {grid}x{grid} grid, {samples} samples/point, {m}x{n}, K={k} ...");
            let (feasible, wins) = figures::figure4(
                &out_dir(a),
                cfg.seed,
                grid,
                samples,
                m,
                n,
                k,
                &cfg.energy,
                &cfg.time,
            )?;
            println!("{feasible} feasible points; wins per criterion:");
            print!("{}", figures::figure4_summary(&wins));
            println!("CSV: figure4.csv");
        }
        "figure5" => {
            let cfg = eval_config(a)?;
            let samples = a.get("samples", 20usize);
            let cols: Vec<usize> = vec![32, 64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384];
            eprintln!("column sweep at H=4, p0=0.55, m=100, {samples} samples ...");
            let rows = figures::figure5(
                &out_dir(a),
                cfg.seed,
                4.0,
                0.55,
                100,
                &cols,
                samples,
                128,
                &cfg.energy,
                &cfg.time,
            )?;
            println!("ratios vs dense (storage / ops / time / energy):");
            for (n, r) in &rows {
                println!(
                    "  n={n:>6}  CSR {:>5.2} {:>5.2} {:>5.2} {:>5.2}   CER {:>5.2} {:>5.2} {:>5.2} {:>5.2}   CSER {:>5.2} {:>5.2} {:>5.2} {:>5.2}",
                    r[1][0], r[1][1], r[1][2], r[1][3],
                    r[2][0], r[2][1], r[2][2], r[2][3],
                    r[3][0], r[3][1], r[3][2], r[3][3],
                );
            }
            println!("CSV: figure5.csv");
        }
        "figure10" => {
            let cfg = eval_config(a)?;
            let evals = tables::eval_vb_networks(&cfg);
            figures::figure10(&evals, &out_dir(a))?;
            println!("CSV: figure10.csv, figure10_boundary.csv");
        }
        "breakdown" => {
            let cfg = eval_config(a)?;
            let net = a.get_str("net", "densenet");
            let mats = figures::synthesize_vb_matrices(&net, cfg.seed, cfg.scale);
            let ev = NetworkEval::run_matrices(
                NetworkSpec::by_name(&net)
                    .ok_or_else(|| anyhow::anyhow!("unknown net '{net}'"))?
                    .name,
                mats.clone(),
                &cfg,
            );
            figures::breakdown(&ev, &mats, &out_dir(a), &cfg.energy, &cfg.time)?;
            println!("CSVs: breakdown_{}_{{storage,ops,time,energy}}.csv", net.to_lowercase());
        }
        "pack" => cmd_pack(a)?,
        "pack-demo" => cmd_pack_demo()?,
        "inspect" if !a.positional.is_empty() => {
            cmd_inspect_pack(Path::new(&a.positional[0]), a)?;
        }
        "inspect" => {
            // Catch `repro inspect --some-flag net.cerpack`, where the
            // parser attached the file to the flag: a silent fall-through
            // to the synthesized-net inspector would be baffling.
            if let Some((k, v)) = a.flags.iter().find(|(_, v)| v.ends_with(".cerpack")) {
                anyhow::bail!(
                    "'{v}' was parsed as the value of --{k}; put the pack file \
                     directly after `inspect`"
                );
            }
            let cfg = eval_config(a)?;
            let net = a.get_str("net", "densenet");
            let spec = NetworkSpec::by_name(&net)
                .ok_or_else(|| anyhow::anyhow!("unknown net '{net}'"))?;
            let target = TargetStats::table_iv(&net)
                .or_else(|| TargetStats::retrained(&net))
                .unwrap_or(TargetStats { p0: 0.36, entropy: 3.73, k: 128 });
            let ev = NetworkEval::run_synthesized(&spec, target, &cfg);
            println!("{}: {} layers, {:.2} MB dense", spec.name, spec.layers.len(), spec.dense_mb());
            for l in &ev.layers {
                println!(
                    "  {:<22} {:>6}x{:<6} patches {:>6}  p0 {:.3}  H {:.3}  kbar {:>7.2}",
                    l.name, l.rows, l.cols, l.patches, l.stats.p0, l.stats.entropy, l.stats.kbar
                );
            }
            let (p0, h, kbar, n) = ev.effective_stats();
            println!("effective: p0 {p0:.2}  H {h:.2}  kbar {kbar:.2}  n {n:.2}");
        }
        "e2e" => {
            let dir = PathBuf::from(a.get_str("artifacts", "artifacts"));
            run_e2e(&dir, a)?;
        }
        "serve" if !a.positional.is_empty() => {
            run_serve_packs(&a.positional, a)?;
        }
        "serve" => {
            let dir = PathBuf::from(a.get_str("artifacts", "artifacts"));
            run_serve_demo(&dir, a)?;
        }
        "serve-net" if !a.positional.is_empty() => cmd_serve_net(&a.positional, a)?,
        "serve-net" => anyhow::bail!(
            "usage: repro serve-net <a.cerpack> [b.cerpack ...] [--addr 127.0.0.1:8080] \
             [--workers N] [--max-inflight N] [--deadline-ms N] [--port-file FILE]"
        ),
        "loadgen" => cmd_loadgen(a)?,
        "reload" if a.positional.len() == 2 => cmd_reload(&a.positional[0], &a.positional[1], a)?,
        "reload" => anyhow::bail!(
            "usage: repro reload <route-name> <file.cerpack> [--addr 127.0.0.1:8080]"
        ),
        "replan" => cmd_replan(a)?,
        "bench-gate" => return cmd_bench_gate(a),
        "calibrate" => cmd_calibrate(a)?,
        "all" => {
            let mut cfg = eval_config(a)?;
            cfg.disk = true; // the shared eval feeds table2's disk columns
            let dir = out_dir(a);
            println!("\n===== table1 =====");
            print!("{}", tables::table1());
            // Evaluate the §V-B zoo once; Tables II–IV and Fig. 10 share it.
            eprintln!("evaluating VGG16 / ResNet152 / DenseNet (scale {}) ...", cfg.scale);
            let vb = tables::eval_vb_networks(&cfg);
            println!("\n===== table2 =====");
            print!("{}", tables::table2(&vb, Some(&dir))?);
            println!("\n===== table3 =====");
            print!("{}", tables::table3(&vb, Some(&dir))?);
            println!("\n===== table4 =====");
            print!("{}", tables::table4(&vb, Some(&dir))?);
            println!("\n===== figure10 =====");
            figures::figure10(&vb, &dir)?;
            println!("CSV: figure10.csv, figure10_boundary.csv");
            drop(vb);
            for c in [
                "table5", "table6", "alexnet", "packed-dense", "figure1", "figure4", "figure5",
            ] {
                println!("\n===== {c} =====");
                let _ = run(c, a)?;
            }
            for net in ["densenet", "resnet152", "vgg16"] {
                println!("\n===== breakdown {net} =====");
                let mut flags = a.flags.clone();
                flags.insert("net".into(), net.into());
                let _ = run(
                    "breakdown",
                    &Args {
                        flags,
                        positional: Vec::new(),
                    },
                )?;
            }
        }
        other => {
            anyhow::bail!("unknown command '{other}' — run `repro help`");
        }
    }
    Ok(ExitCode::SUCCESS)
}

/// `repro pack` — compress a zoo network (synthesize at its Table-IV/V
/// operating point, auto-select each layer's format) and serialize it to a
/// `.cerpack` artifact, then prove the cold-start path by reloading it.
fn cmd_pack(a: &Args) -> anyhow::Result<()> {
    use cer::coordinator::{Engine, PackOptions};
    use cer::formats::FormatKind;
    use cer::networks::weights::synthesize_zoo_layers;
    use cer::pack::stream::EncodeOptions;
    use cer::util::human_bytes;
    use std::time::Instant;

    let net = if a.has("network") {
        a.get_str("network", "densenet")
    } else {
        a.get_str("net", "densenet")
    };
    let cfg = eval_config(a)?;
    let co = CommonOpts::parse(a)?;
    let (objective_str, threads) = (&co.objective_str, co.threads);

    eprintln!(
        "synthesizing {net} at scale {} (seed {}) ...",
        cfg.scale, cfg.seed
    );
    let (spec, layers) = synthesize_zoo_layers(&net, cfg.scale, cfg.seed)
        .ok_or_else(|| anyhow::anyhow!("unknown net '{net}'"))?;
    eprintln!("selecting formats (argmin {objective_str}, modeled at {threads} thread(s)) ...");
    let t0 = Instant::now();
    let engine = Engine::native_auto_in(layers, &cfg.energy, &cfg.time, co.objective, threads);
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;

    let out = a.get_str("out", &format!("{}.cerpack", net.to_lowercase()));
    let path = PathBuf::from(&out);
    let entropy = a.has("entropy");
    let t0 = Instant::now();
    let summary = engine.save_pack_with(
        &path,
        spec.name,
        &format!("argmin {objective_str} (modeled)"),
        &EncodeOptions { entropy },
    )?;
    let save_ms = t0.elapsed().as_secs_f64() * 1e3;
    let (file_bytes, manifest) = (summary.file_bytes, &summary.manifest);

    let dense = manifest.dense_baseline_bytes();
    let analytic = manifest.total_analytic_bits();
    let measured = manifest.total_array_bytes();
    println!(
        "packed {} ({} layers) -> {} ({} on disk)",
        manifest.network,
        manifest.layers.len(),
        path.display(),
        human_bytes(file_bytes as f64)
    );
    let format_counts: Vec<String> = FormatKind::ALL
        .iter()
        .map(|k| {
            let n = manifest.layers.iter().filter(|l| l.format == *k).count();
            format!("{n} {}", k.name())
        })
        .collect();
    println!("  formats: {}", format_counts.join(", "));
    println!(
        "  dense baseline {}  analytic bound {}  measured arrays {}  (x{:.2} vs dense)",
        human_bytes(dense as f64),
        human_bytes(analytic as f64 / 8.0),
        human_bytes(measured as f64),
        dense as f64 / (measured.max(1)) as f64
    );
    match (&summary.coded, entropy) {
        (Some(report), _) => {
            let coded = report.total_on_disk_bytes();
            println!(
                "  entropy tier: {} coded ({} code books, {} Huffman stream(s)) — {:.1}% below raw",
                human_bytes(report.total_array_bytes() as f64),
                human_bytes(report.codebook_bytes as f64),
                report.coded_streams,
                (1.0 - coded as f64 / measured.max(1) as f64) * 100.0
            );
        }
        (None, true) => {
            println!("  entropy tier: no stream paid for itself — pack written raw");
        }
        (None, false) => {}
    }
    println!("  compress+select {build_ms:.0} ms, serialize {save_ms:.1} ms");

    // Cold-start proof: reload from disk and run one forward pass. The
    // pack already stores the thread-aware winners, so the cold engine
    // only configures its plane — no reselection needed.
    let t0 = Instant::now();
    let mut cold = PackOptions::new(&path).threads(threads).open()?;
    let load_ms = t0.elapsed().as_secs_f64() * 1e3;
    if threads > 1 {
        println!("  exec plane: {threads} threads, nnz-balanced shards per layer");
    }
    let x = vec![0.1f32; cold.in_dim()];
    let y = cold.forward(&x, 1)?;
    println!(
        "  cold start: load {:.2} ms ({:.0}x faster than re-compressing), forward OK ({} logits)",
        load_ms,
        build_ms / load_ms.max(1e-9),
        y.len()
    );
    Ok(())
}

/// `repro inspect <file.cerpack>` — verify checksums, dump the header and
/// manifest, compare measured on-disk bytes with the analytic
/// StorageBreakdown bits and the N·H entropy bound, then cold-start an
/// engine from the file.
fn cmd_inspect_pack(path: &Path, a: &Args) -> anyhow::Result<()> {
    use anyhow::Context;
    use cer::coordinator::PackOptions;
    use cer::pack::{DIVERGENCE_FLAG_PCT, Pack, VERSION};
    use cer::util::human_bytes;
    use cer::util::table::TextTable;
    use std::time::Instant;

    // One read, one CRC pass: the full decode below reuses these bytes.
    let inspecting = || format!("inspecting {}", path.display());
    let bytes = std::fs::read(path).with_context(inspecting)?;
    let file_bytes = bytes.len() as u64;
    let t0 = Instant::now();
    let pack = Pack::from_bytes(&bytes).with_context(inspecting)?;
    let decode_ms = t0.elapsed().as_secs_f64() * 1e3;
    let manifest = pack.manifest.clone();
    let coded = pack.coded.clone();
    println!(
        "{}: cerpack v{VERSION}, network '{}', {} layers, {} on disk",
        path.display(),
        manifest.network,
        manifest.layers.len(),
        human_bytes(file_bytes as f64)
    );
    println!("created by: {}", manifest.created_by);
    println!("section checksums: OK");
    if let Some(l) = manifest.layers.first() {
        println!("selection rationale: {}", l.rationale);
    }

    let mut t = TextTable::new(&[
        "layer", "fmt", "shape", "K", "H", "p0", "H-bound", "analytic", "on-disk", "coded", "div%",
    ]);
    let mut flagged = 0usize;
    for (i, l) in manifest.layers.iter().enumerate() {
        let elems = l.rows as u64 * l.cols as u64;
        let div = l.divergence_pct();
        let flag = if div.abs() > DIVERGENCE_FLAG_PCT {
            flagged += 1;
            " !"
        } else {
            ""
        };
        let coded_cell = match &coded {
            Some(r) => human_bytes(r.layer_array_bytes[i] as f64),
            None => "-".to_string(),
        };
        t.row(vec![
            l.name.clone(),
            l.format.name().to_string(),
            format!("{}x{}", l.rows, l.cols),
            format!("{}", l.k),
            format!("{:.2}", l.entropy),
            format!("{:.3}", l.p0),
            human_bytes(l.entropy * elems as f64 / 8.0),
            human_bytes(l.analytic_bits as f64 / 8.0),
            human_bytes(l.array_bytes as f64),
            coded_cell,
            format!("{div:+.2}{flag}"),
        ]);
    }
    print!("{}", t.render());
    let dense = manifest.dense_baseline_bytes();
    let analytic = manifest.total_analytic_bits();
    let measured = manifest.total_array_bytes();
    let total_div = manifest.total_divergence_pct();
    println!(
        "totals: dense {}  analytic {}  on-disk arrays {}  (divergence {total_div:+.2}%, x{:.2} vs dense)",
        human_bytes(dense as f64),
        human_bytes(analytic as f64 / 8.0),
        human_bytes(measured as f64),
        dense as f64 / (measured.max(1)) as f64
    );
    // N·H is the paper's per-element entropy bound summed over the net; it
    // prices element identity only, so index-carrying formats sit above it.
    let nh_bytes: f64 = manifest
        .layers
        .iter()
        .map(|l| l.entropy * (l.rows as u64 * l.cols as u64) as f64 / 8.0)
        .sum();
    if let Some(r) = &coded {
        let total = r.total_on_disk_bytes();
        println!(
            "entropy tier: coded arrays {} + code books {} = {} on disk \
             ({} Huffman stream(s), {:.1}% below raw, {:.2}x the N*H bound of {})",
            human_bytes(r.total_array_bytes() as f64),
            human_bytes(r.codebook_bytes as f64),
            human_bytes(total as f64),
            r.coded_streams,
            (1.0 - total as f64 / measured.max(1) as f64) * 100.0,
            total as f64 / nh_bytes.max(1.0),
            human_bytes(nh_bytes),
        );
    }
    if flagged > 0 {
        println!(
            "WARNING: {flagged} layer(s) diverge >{DIVERGENCE_FLAG_PCT}% between measured \
             on-disk bytes and the analytic storage model"
        );
    }

    if a.has("assert-coded") {
        let r = coded
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("--assert-coded: pack has no entropy-coded tier"))?;
        let total = r.total_on_disk_bytes();
        anyhow::ensure!(
            total <= measured,
            "--assert-coded: coded on-disk bytes {total} exceed raw array bytes {measured}"
        );
        println!("assert-coded: OK ({total} <= {measured} raw)");
    }
    if a.has("assert-coded-within") {
        let pct: f64 = a
            .get_str("assert-coded-within", "")
            .parse()
            .map_err(|_| anyhow::anyhow!("--assert-coded-within needs a percentage, e.g. 250"))?;
        let r = coded.as_ref().ok_or_else(|| {
            anyhow::anyhow!("--assert-coded-within: pack has no entropy-coded tier")
        })?;
        let total = r.total_on_disk_bytes() as f64;
        let limit = nh_bytes * (1.0 + pct / 100.0);
        anyhow::ensure!(
            total <= limit,
            "--assert-coded-within {pct}: coded on-disk bytes {total:.0} exceed \
             {limit:.0} (N*H bound {nh_bytes:.0} B + {pct}%)"
        );
        println!(
            "assert-coded-within {pct}%: OK ({total:.0} <= {limit:.0}, N*H {nh_bytes:.0} B)"
        );
    }

    // Cold start from the already-decoded payloads.
    if pack.layers.is_empty() {
        println!("cold start: skipped (pack has no layers)");
        return Ok(());
    }
    let mut engine = PackOptions::from_data(pack).open()?;
    let x = vec![0.1f32; engine.in_dim()];
    let y = engine.forward(&x, 1)?;
    println!(
        "cold start: decoded + built engine in {decode_ms:.2} ms, forward OK ({} logits)",
        y.len()
    );
    Ok(())
}

/// `repro pack-demo` — smallest end-to-end artifact demo: pack the paper's
/// 5x12 running example, reload it cold, and check one dot product.
fn cmd_pack_demo() -> anyhow::Result<()> {
    use cer::coordinator::PackOptions;
    use cer::formats::FormatKind;
    use cer::kernels::AnyMatrix;
    use cer::pack::Pack;

    let m = cer::paper_example_matrix();
    let pack = Pack::from_layers(
        "paper-example",
        "fixed CSER (demo)",
        vec![(
            "example".to_string(),
            AnyMatrix::encode(FormatKind::Cser, &m),
            vec![0.0; m.rows()],
        )],
    );
    let path = std::env::temp_dir().join(format!("cer-pack-demo-{}.cerpack", std::process::id()));
    let (bytes, manifest) = pack.write_to(&path)?;
    let l = &manifest.layers[0];
    println!(
        "packed the paper's 5x12 example as CSER: {bytes} B file, {} B arrays vs {} bits analytic",
        l.array_bytes, l.analytic_bits
    );
    let mut engine = PackOptions::new(&path).open()?;
    std::fs::remove_file(&path).ok();
    let x: Vec<f32> = vec![1.0; 12];
    let y = engine.forward(&x, 1)?;
    println!("cold-start row sums: {y:?} (row 2 = 24 per the paper's worked example)");
    anyhow::ensure!((y[1] - 24.0).abs() < 1e-6, "row-2 dot product mismatch");
    Ok(())
}

/// The e2e driver shared by `repro e2e` (also available as
/// `examples/e2e_inference.rs`).
fn run_e2e(artifacts: &Path, a: &Args) -> anyhow::Result<()> {
    use cer::coordinator::{Backend, Engine};
    use cer::runtime::MlpArtifacts;

    let art = MlpArtifacts::load(artifacts)?;
    println!(
        "loaded e2e model: {} layers, batch {}, build-time accuracies float {:.4} / quant {:.4}",
        art.layers.len(),
        art.batch,
        art.accuracy_float,
        art.accuracy_quant
    );
    let n_batches = a.get("batches", usize::MAX);
    let co = CommonOpts::parse(a)?;
    let (objective, threads, kernel) = (co.objective, co.threads, co.kernel);
    if kernel != cer::kernels::KernelBackend::Scalar {
        println!("native kernel backend: {kernel} (scalar stays the bit-exactness reference)");
    }
    for backend in [Backend::Native, Backend::XlaDense, Backend::XlaCser] {
        // XLA backends are unavailable when built without the `xla`
        // feature (or when PJRT fails) — report and keep going. Native
        // failures are real errors and still abort the command. The
        // native engine selects its formats against the configured
        // thread count (and runs its exec plane at it).
        let mut engine = match Engine::from_artifacts_in(&art, backend, objective, threads) {
            Ok(e) => e,
            Err(e) if backend != Backend::Native => {
                println!("{backend:?}: skipped ({e})");
                continue;
            }
            Err(e) => return Err(e),
        };
        if backend == Backend::Native {
            engine.set_kernel_backend(kernel);
        }
        let t0 = std::time::Instant::now();
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut b = 0usize;
        let mut start = 0usize;
        while start < art.n_test && b < n_batches {
            let (x, y, valid) = art.test_batch(start);
            let batch = engine.required_batch().unwrap_or(art.batch);
            let pred = engine.classify(&x[..batch * art.in_dim()], batch)?;
            for i in 0..valid {
                if pred[i] == y[i] as usize {
                    correct += 1;
                }
            }
            total += valid;
            start += art.batch;
            b += 1;
        }
        let elapsed = t0.elapsed();
        println!(
            "{:?}: accuracy {:.4} ({correct}/{total}), {:.2} ms total, {:.1} µs/sample, formats {:?}, weights {:.1} KB",
            backend,
            correct as f64 / total as f64,
            elapsed.as_secs_f64() * 1e3,
            elapsed.as_secs_f64() * 1e6 / total as f64,
            engine.formats(),
            engine.storage_bits() as f64 / 8.0 / 1024.0,
        );
    }
    Ok(())
}

/// `repro serve a.cerpack [b.cerpack ...]` — serve one or more packed
/// networks through the zero-copy cold-start path: each pack is mapped
/// once (`Arc<PackMap>`), `--workers N` engines per pack share that one
/// mapping (N engines × M kernel threads, round-robined), and demo
/// traffic is routed per request by pack name. With `--verify`, every
/// reply is checked bit-for-bit against an owned-storage engine loaded
/// through the copying reader — the acceptance check that the mmap path
/// changes *where* bytes live, never *what* the kernels compute.
fn run_serve_packs(packs: &[String], a: &Args) -> anyhow::Result<()> {
    use cer::coordinator::batcher::BatcherConfig;
    use cer::coordinator::{PackOptions, PackRouter, ServerConfig, WorkerSet};
    use cer::pack::map::PackMap;
    use cer::util::{human_bytes, Rng};

    let workers = a.get("workers", 1usize).max(1);
    let requests = a.get("requests", 128usize);
    let verify = a.has("verify");
    let prefault = a.has("prefault");
    let co = CommonOpts::parse(a)?;
    let threads = co.threads;
    // --verify promises bit-identity to the owned-storage path, which only
    // the scalar reference kernels provide — force them and say so.
    let mut kernel = co.kernel;
    if verify && kernel != cer::kernels::KernelBackend::Scalar {
        eprintln!("serve: --verify forces the scalar kernel backend (bit-identity reference)");
        kernel = cer::kernels::KernelBackend::Scalar;
    }
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: a.get("max-batch", 32usize),
            max_delay_us: a.get("max-delay-us", 2_000u64),
        },
        threads: Some(threads),
        kernel,
    };

    let mut router = PackRouter::new();
    // Owned-path reference engines for --verify, plus per-pack input dims.
    let mut reference: Vec<(String, cer::coordinator::Engine)> = Vec::new();
    let mut dims: Vec<(String, usize)> = Vec::new();
    for p in packs {
        let path = Path::new(p);
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or(p)
            .to_string();
        anyhow::ensure!(
            !dims.iter().any(|(n, _)| n == &name),
            "duplicate pack name '{name}' — serve distinctly named packs"
        );
        let map = PackMap::open(path)
            .map_err(|e| anyhow::anyhow!("mapping {}: {e}", path.display()))?;
        // One probe engine up front: input dim, residency report, and an
        // early error instead of a failed first request.
        let probe = PackOptions::from_map(&map).prefault(prefault).open()?;
        let res = probe.storage_residency();
        println!(
            "{name}: {} on disk ({}), {workers} worker(s) x {threads} thread(s) — \
             {} mapped / {} owned per engine",
            human_bytes(map.len() as f64),
            if map.is_mmap() { "mmap" } else { "heap-mapped" },
            human_bytes(res.mapped_bytes as f64),
            human_bytes(res.owned_bytes as f64),
        );
        dims.push((name.clone(), probe.in_dim()));
        if verify {
            reference.push((name.clone(), PackOptions::new(path).open()?));
        }
        drop(probe);
        let map_for_workers = map.clone();
        router.add(
            name,
            WorkerSet::spawn(workers, cfg, move |_i| {
                PackOptions::from_map(&map_for_workers).open()
            }),
        );
    }

    println!(
        "serving {} pack(s) [{}], {requests} request(s), routed per request ...",
        dims.len(),
        router.names().join(", ")
    );
    // (pack index, input, reply receiver) per in-flight request.
    type Pending = (usize, Vec<f32>, std::sync::mpsc::Receiver<anyhow::Result<Vec<f32>>>);
    let mut rng = Rng::new(a.get("seed", 0xCE5Eu64));
    let t0 = std::time::Instant::now();
    let mut pending: Vec<Pending> = Vec::new();
    for i in 0..requests {
        let (name, in_dim) = &dims[i % dims.len()];
        let x: Vec<f32> = (0..*in_dim).map(|_| rng.f32() - 0.5).collect();
        let rx = router.submit(name, x.clone())?;
        pending.push((i % dims.len(), x, rx));
    }
    let mut verified = 0usize;
    for (pack_idx, x, rx) in pending {
        let got = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server worker terminated"))??;
        if verify {
            let (_, engine) = &mut reference[pack_idx];
            let want = engine.forward(&x, 1)?;
            anyhow::ensure!(
                got == want,
                "mmap-served reply diverges from the owned-storage path (pack '{}')",
                dims[pack_idx].0
            );
            verified += 1;
        }
    }
    let dt = t0.elapsed();
    for (name, _) in &dims {
        let ws = router.route(name).expect("registered");
        let mut per_worker = Vec::new();
        for w in 0..ws.workers() {
            per_worker.push(
                ws.worker_metrics(w)
                    .completed
                    .load(std::sync::atomic::Ordering::Relaxed)
                    .to_string(),
            );
        }
        println!(
            "  {name}: {} completed (per worker: {})",
            ws.completed_total(),
            per_worker.join("/")
        );
    }
    println!(
        "done: {:.1} req/s{}",
        requests as f64 / dt.as_secs_f64(),
        if verify {
            format!(", {verified}/{requests} replies verified bit-identical to the owned path")
        } else {
            String::new()
        }
    );
    router.shutdown();
    Ok(())
}

/// `repro bench-gate --fresh BENCH_x.json --baseline ci/baselines/BENCH_x.json`
/// — diff a fresh bench artifact against the committed baseline and fail
/// (non-zero exit) on any tracked metric regressing beyond
/// `--max-regress-pct` (default 25). An empty `{}` (or absent) baseline
/// makes this a **seeding** run: gating is inert, every would-be-gated
/// metric is announced with a loud `SEEDING (no baseline)` line, and the
/// process exits with the distinct code **2** (pass = 0, regression or
/// error = 1) so CI logs can't mistake an unarmed gate for a green one.
/// `--update` writes the fresh artifact over the baseline (for
/// maintainers recording a new trajectory point).
fn cmd_bench_gate(a: &Args) -> anyhow::Result<ExitCode> {
    use cer::util::benchgate::gate;
    use cer::util::json;

    let fresh_path = a.get_str("fresh", "");
    let baseline_path = a.get_str("baseline", "");
    anyhow::ensure!(
        !fresh_path.is_empty() && !baseline_path.is_empty(),
        "usage: repro bench-gate --fresh <new.json> --baseline <committed.json> \
         [--max-regress-pct 25] [--update]"
    );
    let max_regress = a.get("max-regress-pct", 25.0f64);
    let fresh_text = std::fs::read_to_string(&fresh_path)
        .map_err(|e| anyhow::anyhow!("reading {fresh_path}: {e}"))?;
    let fresh = json::parse(&fresh_text)
        .map_err(|e| anyhow::anyhow!("parsing {fresh_path}: {e}"))?;
    let baseline = match std::fs::read_to_string(&baseline_path) {
        Ok(text) => json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {baseline_path}: {e}"))?,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            println!("baseline {baseline_path} absent — treating as empty (seeding run)");
            json::Json::Obj(Vec::new())
        }
        Err(e) => return Err(anyhow::anyhow!("reading {baseline_path}: {e}")),
    };

    let report = gate(&baseline, &fresh, max_regress);
    let update_baseline = || -> anyhow::Result<()> {
        if let Some(dir) = Path::new(&baseline_path).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::copy(&fresh_path, &baseline_path)
            .map_err(|e| anyhow::anyhow!("updating {baseline_path}: {e}"))?;
        println!("updated baseline {baseline_path}");
        Ok(())
    };
    if report.seeding {
        for key in &report.only_fresh {
            println!("SEEDING (no baseline): {key}");
        }
        println!(
            "bench-gate: gating is INERT — {} tracked metric(s) have no baseline to \
             compare against; commit {fresh_path} as {baseline_path} (or re-run with \
             --update) to arm the gate",
            report.only_fresh.len()
        );
        if a.has("update") {
            update_baseline()?;
        }
        // Distinct exit code: not a pass (nothing was gated), not a
        // failure (nothing regressed). CI treats 2 as "inert, proceed".
        return Ok(ExitCode::from(2));
    }
    print!("{}", report.render(40));
    println!(
        "bench-gate: {} tracked metric(s) compared at ±{max_regress}% threshold",
        report.compared.len()
    );
    let failures: Vec<String> = report.failures().map(|c| c.key.clone()).collect();
    if a.has("update") {
        // Never bake a regressed run into the baseline: --update applies
        // only when the gate passes (a deliberate reset goes through
        // editing the baseline, with the regression visible in review).
        if failures.is_empty() {
            update_baseline()?;
        } else {
            println!("--update skipped: the gate failed, baseline left unchanged");
        }
    }
    anyhow::ensure!(
        failures.is_empty(),
        "bench regression >{max_regress}% in {} metric(s): {}",
        failures.len(),
        failures.join(", ")
    );
    Ok(ExitCode::SUCCESS)
}

/// `repro calibrate` — run the cache-ruined per-kernel micro-benchmarks,
/// fit per-(format, backend) time-model scale/intercept constants plus
/// the measured pool dispatch overhead, and write them to `--out`
/// (default calibration.json; feed back through `--calibration`) and the
/// raw measured-vs-modeled rows to `--bench-out` (default
/// BENCH_calibration.json, tracked by the CI bench gate).
fn cmd_calibrate(a: &Args) -> anyhow::Result<()> {
    use cer::costmodel::calibrate::bench_json;
    use cer::costmodel::run_calibration;
    use cer::formats::FormatKind;
    use cer::kernels::KernelBackend;

    let smoke = a.has("smoke");
    let backends: Vec<KernelBackend> = CommonOpts::backends_flag(a)?;
    eprintln!(
        "calibrating {} ({} sizes, cache-ruined best-of-N) ...",
        backends.iter().map(|b| b.name()).collect::<Vec<_>>().join(" + "),
        if smoke { "smoke" } else { "full" }
    );
    let t0 = std::time::Instant::now();
    let (cal, rows) = run_calibration(smoke, &backends);
    let secs = t0.elapsed().as_secs_f64();

    let out = a.get_str("out", "calibration.json");
    std::fs::write(&out, cal.to_json_string())
        .map_err(|e| anyhow::anyhow!("writing {out}: {e}"))?;
    let bench_out = a.get_str("bench-out", "BENCH_calibration.json");
    std::fs::write(&bench_out, bench_json(&rows))
        .map_err(|e| anyhow::anyhow!("writing {bench_out}: {e}"))?;

    println!(
        "calibrated {} point(s) in {secs:.1}s: dispatch overhead {:.0} ns",
        rows.len(),
        cal.dispatch_overhead_ns
    );
    for fit in &cal.fits {
        let per_fmt: Vec<String> = FormatKind::ALL
            .iter()
            .enumerate()
            .map(|(i, k)| {
                format!("{} x{:.2}+{:.0}ns", k.name(), fit.scale[i], fit.intercept_ns[i])
            })
            .collect();
        println!("  {:<6} {}", fit.backend.name(), per_fmt.join("  "));
        // How well the fitted line explains the points it was fit on —
        // a large error here means the two sizes straddle a cache cliff.
        let mut worst = 0.0f64;
        for r in rows.iter().filter(|r| r.backend == fit.backend) {
            let i = FormatKind::ALL.iter().position(|k| *k == r.format).unwrap_or(0);
            let predicted = fit.scale[i] * r.modeled_ns + fit.intercept_ns[i];
            if r.measured_ns > 0.0 {
                worst = worst.max((predicted - r.measured_ns).abs() / r.measured_ns);
            }
        }
        println!("         worst fitted-vs-measured error {:.1}%", worst * 100.0);
    }
    println!("wrote {out} (apply with --calibration) and {bench_out}");
    Ok(())
}

/// `repro serve-net a.cerpack [b.cerpack ...]` — the network front end:
/// put an HTTP/1.1 socket in front of the mmap-shared worker plane.
/// Requests hit bounded admission (429 + Retry-After when full) and
/// per-request deadlines (504 before a worker is touched); SIGTERM (or
/// `POST /admin/shutdown`) stops accepting, answers everything in
/// flight, and exits 0. `POST /admin/reload` hot-swaps a route's pack
/// under traffic.
fn cmd_serve_net(packs: &[String], a: &Args) -> anyhow::Result<()> {
    use cer::coordinator::ServerConfig;
    use cer::coordinator::batcher::BatcherConfig;
    use cer::serve::{
        install_term_handler, serve, termination_requested, HotRouter, ServeOptions, ServeState,
    };
    use std::time::Duration;

    let addr = a.get_str("addr", "127.0.0.1:8080");
    let workers = a.get("workers", 1usize).max(1);
    let co = CommonOpts::parse(a)?;
    let (threads, kernel) = (co.threads, co.kernel);
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: a.get("max-batch", 32usize),
            max_delay_us: a.get("max-delay-us", 2_000u64),
        },
        threads: Some(threads),
        kernel,
    };
    let defaults = ServeOptions::default();
    let opts = ServeOptions {
        max_inflight: a.get("max-inflight", defaults.max_inflight),
        default_deadline_ms: a.get("deadline-ms", defaults.default_deadline_ms),
        max_body_bytes: a.get("max-body-bytes", defaults.max_body_bytes),
    };
    let router = HotRouter::new(cfg, workers);
    for p in packs {
        let path = Path::new(p);
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or(p.as_str())
            .to_string();
        router.add_pack(&name, path)?;
        let ep = router.endpoint(&name).expect("just added");
        println!(
            "route \"{name}\": in_dim {} -> out_dim {} ({workers} worker(s) x {threads} \
             thread(s), {kernel} kernels) from {}",
            ep.in_dim,
            ep.out_dim,
            path.display()
        );
    }
    install_term_handler();
    let state = ServeState::new(router, opts);
    let handle = serve(&addr, state).map_err(|e| anyhow::anyhow!("binding {addr}: {e}"))?;
    println!(
        "listening on http://{} — POST /v1/infer, GET /healthz, GET /metrics, \
         POST /admin/{{reload,replan,drain,shutdown}}; SIGTERM drains",
        handle.addr()
    );
    // CI binds port 0 and reads the resolved address from --port-file.
    let port_file = a.get_str("port-file", "");
    if !port_file.is_empty() {
        std::fs::write(&port_file, handle.addr().to_string())
            .map_err(|e| anyhow::anyhow!("writing {port_file}: {e}"))?;
    }
    loop {
        if termination_requested() {
            eprintln!("repro serve-net: termination signal — draining");
            break;
        }
        if handle.shutdown_requested() {
            eprintln!("repro serve-net: admin shutdown — draining");
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    let drain = Duration::from_secs(a.get("drain-timeout-s", 30u64));
    anyhow::ensure!(
        handle.shutdown(drain),
        "drain timed out after {drain:?} with requests still in flight"
    );
    println!("drained cleanly");
    Ok(())
}

/// `repro loadgen` — drive a running `serve-net` with closed-loop
/// (`--concurrency` list) and open-loop Poisson (`--rates` list) steps,
/// and write the `BENCH_serve.json` artifact (throughput + p50/p99/p999
/// per step, knee point). `--smoke` self-hosts a loopback server over a
/// synthesized pack and verifies replies bit-identical to the in-process
/// path — the CI entry point.
fn cmd_loadgen(a: &Args) -> anyhow::Result<()> {
    use cer::serve::loadgen::{self, LoadgenConfig};

    fn list<T: std::str::FromStr>(s: &str) -> Vec<T> {
        s.split(',').filter_map(|p| p.trim().parse().ok()).collect()
    }

    let out = PathBuf::from(a.get_str("out", "BENCH_serve.json"));
    let seed = a.get("seed", 42u64);
    if a.has("smoke") {
        let summary = loadgen::smoke(&out, seed)?;
        println!("{summary}");
        return Ok(());
    }
    let defaults = LoadgenConfig::default();
    let trace = a.get_str("trace", "");
    let cfg = LoadgenConfig {
        addr: a.get_str("addr", &defaults.addr),
        concurrency: list(&a.get_str("concurrency", "4")),
        rates: list(&a.get_str("rates", "200,400,800")),
        duration_ms: a.get("duration-ms", defaults.duration_ms),
        conns: a.get("conns", defaults.conns),
        deadline_ms: a.get("deadline-ms", defaults.deadline_ms),
        seed,
        trace: (!trace.is_empty()).then(|| PathBuf::from(&trace)),
    };
    let mode = a.get_str("mode", "both");
    let cfg = match mode.as_str() {
        "both" => cfg,
        "closed" => LoadgenConfig {
            rates: Vec::new(),
            ..cfg
        },
        "open" => LoadgenConfig {
            concurrency: Vec::new(),
            ..cfg
        },
        other => anyhow::bail!("unknown --mode '{other}' (closed|open|both)"),
    };
    anyhow::ensure!(
        cfg.trace.is_some() || !(cfg.rates.is_empty() && cfg.concurrency.is_empty()),
        "nothing to run: --rates and --concurrency are both empty (and no --trace)"
    );
    let verify = a.get_str("verify-pack", "");
    let verify_path = (!verify.is_empty()).then(|| PathBuf::from(&verify));
    let summary = loadgen::run(&cfg, &out, verify_path.as_deref())?;
    println!("{summary}");
    Ok(())
}

/// `repro reload <route> <file.cerpack>` — ask a running `serve-net` to
///// hot-swap the pack behind a route. The swap is atomic under traffic:
/// in-flight requests finish on the old weights, the old mapping drops
/// after they drain.
fn cmd_reload(name: &str, pack: &str, a: &Args) -> anyhow::Result<()> {
    use cer::serve::http::{json_escape, HttpClient, Request};
    use std::time::Duration;

    let addr = a.get_str("addr", "127.0.0.1:8080");
    // The server opens the path itself — send it absolute so a client
    // launched from another directory still names the same file.
    let path = std::fs::canonicalize(pack)
        .map_err(|e| anyhow::anyhow!("resolving {pack}: {e}"))?;
    let mut client = HttpClient::connect(&addr, Duration::from_secs(10))
        .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))?;
    let body = format!(
        "{{\"name\":\"{}\",\"path\":\"{}\"}}",
        json_escape(name),
        json_escape(&path.display().to_string())
    );
    let resp = client
        .request(&Request::new("POST", "/admin/reload").json(body))
        .map_err(|e| anyhow::anyhow!("reload request: {e}"))?;
    anyhow::ensure!(
        resp.status == 200,
        "reload failed ({}): {}",
        resp.status,
        resp.body_str()
    );
    let doc = cer::util::json::parse(&resp.body_str())
        .map_err(|e| anyhow::anyhow!("reload reply: {e}"))?;
    let generation = doc
        .get("generation")
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow::anyhow!("reload reply missing generation"))?;
    println!(
        "route \"{name}\" now serving {} (generation {generation})",
        path.display()
    );
    Ok(())
}

/// `repro replan` — ask a running `serve-net` to re-plan its engines
/// live: reconfigure the exec plane's thread count, optionally re-run
/// the smoke time-model calibration on each quiesced worker, and re-run
/// thread-aware format selection. Weights, routes and generations are
/// untouched; only the execution plane and per-layer format choices
/// move. `--expect-flip` exits non-zero when no layer changed format —
/// CI uses it to assert the reconfiguration was observable.
fn cmd_replan(a: &Args) -> anyhow::Result<()> {
    use cer::serve::http::{json_escape, HttpClient, Request};
    use std::time::Duration;

    let addr = a.get_str("addr", "127.0.0.1:8080");
    let co = CommonOpts::parse(a)?;
    let mut fields = Vec::new();
    let name = a.get_str("name", "");
    if !name.is_empty() {
        fields.push(format!("\"name\":\"{}\"", json_escape(&name)));
    }
    if let Some(t) = co.threads_requested {
        fields.push(format!("\"threads\":{t}"));
    }
    if a.has("calibrate") {
        fields.push("\"calibrate\":true".to_string());
    }
    if co.objective_requested {
        fields.push(format!("\"objective\":\"{}\"", co.objective_str));
    }
    let body = format!("{{{}}}", fields.join(","));
    // Calibration runs micro-benches per worker before the reply comes
    // back — give the request a generous client-side timeout.
    let mut client = HttpClient::connect(&addr, Duration::from_secs(120))
        .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))?;
    let resp = client
        .request(&Request::new("POST", "/admin/replan").json(body))
        .map_err(|e| anyhow::anyhow!("replan request: {e}"))?;
    anyhow::ensure!(
        resp.status == 200,
        "replan failed ({}): {}",
        resp.status,
        resp.body_str()
    );
    let doc = cer::util::json::parse(&resp.body_str())
        .map_err(|e| anyhow::anyhow!("replan reply: {e}"))?;
    let flipped = doc
        .get("flipped")
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow::anyhow!("replan reply missing flipped count"))? as u64;
    for pack in doc
        .get("packs")
        .map(|p| p.items())
        .unwrap_or_default()
    {
        let pname = pack.get("pack").and_then(|v| v.as_str()).unwrap_or("?");
        let workers = pack.get("workers").map(|w| w.items()).unwrap_or_default();
        let threads = workers
            .first()
            .and_then(|w| w.get("threads"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0) as usize;
        let fmt = |key: &str| -> String {
            workers
                .first()
                .and_then(|w| w.get(key))
                .map(|arr| {
                    arr.items()
                        .iter()
                        .filter_map(|v| v.as_str())
                        .collect::<Vec<_>>()
                        .join(",")
                })
                .unwrap_or_default()
        };
        println!(
            "route \"{pname}\": {} worker(s) now at {threads} thread(s), formats [{}] -> [{}]",
            workers.len(),
            fmt("before"),
            fmt("after"),
        );
    }
    println!("{flipped} layer format flip(s) across all workers");
    if a.has("expect-flip") {
        anyhow::ensure!(
            flipped > 0,
            "--expect-flip: re-planning changed no layer's format"
        );
    }
    Ok(())
}

fn run_serve_demo(artifacts: &Path, a: &Args) -> anyhow::Result<()> {
    use cer::coordinator::{Backend, Engine, InferenceServer, ServerConfig};
    use cer::coordinator::batcher::BatcherConfig;
    use cer::runtime::MlpArtifacts;

    let art = MlpArtifacts::load(artifacts)?;
    let requests = a.get("requests", 512usize);
    let co = CommonOpts::parse(a)?;
    let (objective, objective_str, threads) = (co.objective, &co.objective_str, co.threads);
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: a.get("max-batch", 32usize),
            max_delay_us: a.get("max-delay-us", 2_000u64),
        },
        threads: Some(threads),
        kernel: co.kernel,
    };
    if threads > 1 {
        println!(
            "engine exec plane: {threads} threads (nnz-balanced row shards, formats \
             selected for argmin {objective_str} at {threads} thread(s))"
        );
    }
    let art_clone = art.clone();
    let srv = InferenceServer::spawn(
        move || Engine::from_artifacts_in(&art_clone, Backend::Native, objective, threads),
        cfg,
    );
    println!("serving {requests} requests through the dynamic batcher ...");
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| {
            let s = i % art.n_test;
            srv.submit(art.test_x[s * art.in_dim()..(s + 1) * art.in_dim()].to_vec())
        })
        .collect();
    let mut correct = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let logits = rx.recv()??;
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .unwrap()
            .0;
        if pred == art.test_y[i % art.n_test] as usize {
            correct += 1;
        }
    }
    let dt = t0.elapsed();
    println!(
        "done: accuracy {:.4}, {:.1} req/s, metrics: {}",
        correct as f64 / requests as f64,
        requests as f64 / dt.as_secs_f64(),
        srv.metrics().summary()
    );
    srv.shutdown();
    Ok(())
}
