//! Figures 1, 4, 5, 6–9 (and the 12–14 per-network variants), 10 — each as
//! a CSV under `results/` plus a terminal summary.

use std::io;
use std::path::Path;

use crate::costmodel::{trace_matvec, Criterion4, DistStats, EnergyModel, OpClass, TimeModel};
use crate::formats::FormatKind;
use crate::harness::eval::{NetworkEval, NFMT};
use crate::kernels::AnyMatrix;
use crate::networks::weights::synthesize_float_layer;
use crate::networks::zoo::{LayerKind, LayerSpec, NetworkSpec};
use crate::stats::entropy::{max_entropy, min_entropy};
use crate::stats::quantize::uniform_quantize;
use crate::stats::synth::PlanePoint;
use crate::util::csv::CsvWriter;
use crate::util::Rng;

/// Fig. 1 — distribution of the quantized VGG-16 last layer (1000×4096,
/// 7-bit uniform quantizer): writes the pmf and the top-15 values.
/// Returns (mode value, mode frequency, K).
pub fn figure1(out_dir: &Path, seed: u64) -> io::Result<(f32, f64, usize)> {
    let spec = LayerSpec {
        name: "vgg16.fc8".into(),
        kind: LayerKind::Fc,
        rows: 1000,
        cols: 4096,
        patches: 1,
    };
    let mut rng = Rng::new(seed ^ 0xF161);
    // Scale-mixture weights → realistic heavy-tailed layer (DESIGN.md §4).
    let w = synthesize_float_layer(&spec, 0.008, 0.03, 6.0, &mut rng);
    let q = uniform_quantize(&w, 7);
    let codebook = crate::formats::codebook::frequency_codebook(&q);
    let n = (q.rows() * q.cols()) as f64;
    let mut csv = CsvWriter::create(out_dir.join("figure1_pmf.csv"), &["value", "pmf"])?;
    let mut by_value = codebook.clone();
    by_value.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for (v, c) in &by_value {
        csv.row(&[format!("{v}"), format!("{}", *c as f64 / n)])?;
    }
    csv.finish()?;
    let mut top = CsvWriter::create(out_dir.join("figure1_top15.csv"), &["rank", "value", "freq"])?;
    for (i, (v, c)) in codebook.iter().take(15).enumerate() {
        top.row(&[
            format!("{}", i + 1),
            format!("{v}"),
            format!("{}", *c as f64 / n),
        ])?;
    }
    top.finish()?;
    Ok((codebook[0].0, codebook[0].1 as f64 / n, codebook.len()))
}

/// Fig. 4 — winner map on the (H, p₀) plane.
///
/// For `grid × grid` points, samples `samples` matrices of `m × n` with
/// `K = k` values, averages the four criteria per format and records which
/// of {dense, CSR, CER/CSER} wins each criterion. Infeasible points are
/// skipped. Writes `figure4.csv` with one row per feasible point.
/// Returns (feasible points, per-criterion win counts [dense, csr, proposed]).
#[allow(clippy::too_many_arguments)]
pub fn figure4(
    out_dir: &Path,
    seed: u64,
    grid: usize,
    samples: usize,
    m: usize,
    n: usize,
    k: usize,
    energy: &EnergyModel,
    time: &TimeModel,
) -> io::Result<(usize, [[u32; 3]; 4])> {
    let mut csv = CsvWriter::create(
        out_dir.join("figure4.csv"),
        &[
            "H",
            "p0",
            "win_storage",
            "win_ops",
            "win_time",
            "win_energy",
        ],
    )?;
    let mut rng = Rng::new(seed ^ 0xF164);
    let mut feasible = 0usize;
    let mut wins = [[0u32; 3]; 4];
    let h_top = (k as f64).log2();
    for gi in 0..grid {
        // p0 ∈ (0, 1): grid midpoints.
        let p0 = (gi as f64 + 0.5) / grid as f64;
        for gj in 0..grid {
            let h = h_top * (gj as f64 + 0.5) / grid as f64;
            if h < min_entropy(p0) || h > max_entropy(p0, k) {
                continue;
            }
            let Some(point) = PlanePoint::synthesize(h, p0, k) else {
                continue;
            };
            feasible += 1;
            // Average criteria over samples.
            let mut acc = [[0.0f64; 4]; NFMT];
            for _ in 0..samples {
                let mat = point.sample_matrix(m, n, &mut rng);
                for (fi, kind) in FormatKind::ALL.iter().enumerate() {
                    let c = Criterion4::evaluate(&AnyMatrix::encode(*kind, &mat), energy, time);
                    for ci in 0..4 {
                        acc[fi][ci] += c.get(ci);
                    }
                }
            }
            // Winner per criterion, folded to {0: dense, 1: csr,
            // 2: proposed (cer|cser|bsr|tnn)}.
            let mut row = vec![format!("{h:.4}"), format!("{p0:.4}")];
            for ci in 0..4 {
                let mut best = 0usize;
                for fi in 1..NFMT {
                    if acc[fi][ci] < acc[best][ci] {
                        best = fi;
                    }
                }
                let folded = match best {
                    0 => 0,
                    1 => 1,
                    _ => 2,
                };
                wins[ci][folded] += 1;
                row.push(["dense", "csr", "proposed"][folded].to_string());
            }
            csv.row(&row)?;
        }
    }
    csv.finish()?;
    Ok((feasible, wins))
}

/// Fig. 5 — efficiency ratios vs the dense format as the column count n
/// grows (H = 4, p₀ = 0.55, m = 100 in the paper). Writes `figure5.csv`
/// with per-(n, format) ratio rows for all four criteria.
#[allow(clippy::too_many_arguments)]
pub fn figure5(
    out_dir: &Path,
    seed: u64,
    h: f64,
    p0: f64,
    m: usize,
    cols: &[usize],
    samples: usize,
    k: usize,
    energy: &EnergyModel,
    time: &TimeModel,
) -> io::Result<Vec<(usize, [[f64; 4]; NFMT])>> {
    let point = PlanePoint::synthesize(h, p0, k).expect("feasible (H,p0)");
    let mut rng = Rng::new(seed ^ 0xF165);
    let mut csv = CsvWriter::create(
        out_dir.join("figure5.csv"),
        &["n", "format", "storage_ratio", "ops_ratio", "time_ratio", "energy_ratio"],
    )?;
    let mut out = Vec::new();
    for &n in cols {
        let mut acc = [[0.0f64; 4]; NFMT];
        for _ in 0..samples {
            let mat = point.sample_matrix(m, n, &mut rng);
            for (fi, kind) in FormatKind::ALL.iter().enumerate() {
                let c = Criterion4::evaluate(&AnyMatrix::encode(*kind, &mat), energy, time);
                for ci in 0..4 {
                    acc[fi][ci] += c.get(ci);
                }
            }
        }
        // Ratios vs dense (dense/X, >1 = better than dense).
        let mut ratios = [[0.0f64; 4]; NFMT];
        for fi in 0..NFMT {
            for ci in 0..4 {
                ratios[fi][ci] = acc[0][ci] / acc[fi][ci];
            }
            csv.row(&[
                format!("{n}"),
                FormatKind::ALL[fi].name().to_string(),
                format!("{:.4}", ratios[fi][0]),
                format!("{:.4}", ratios[fi][1]),
                format!("{:.4}", ratios[fi][2]),
                format!("{:.4}", ratios[fi][3]),
            ])?;
        }
        out.push((n, ratios));
    }
    csv.finish()?;
    Ok(out)
}

/// Figs. 6–9 (and 12–14 for other nets) — per-format breakdowns for one
/// evaluated network:
///
/// * `*_storage.csv` — bits per data-structure part (Fig. 6),
/// * `*_ops.csv` / `*_time.csv` / `*_energy.csv` — totals per operation
///   class (Figs. 7–9), patch-weighted like the tables.
pub fn breakdown(
    ev: &NetworkEval,
    matrices: &[(String, u64, crate::formats::Dense)],
    out_dir: &Path,
    energy: &EnergyModel,
    time: &TimeModel,
) -> io::Result<()> {
    let tag = ev.net.to_ascii_lowercase();
    // Storage parts.
    let mut s_csv = CsvWriter::create(
        out_dir.join(format!("breakdown_{tag}_storage.csv")),
        &["format", "part", "bits"],
    )?;
    let part_names = [
        "Omega", "colI", "OmegaI", "OmegaPtr", "rowPtr", "codes", "blocks", "blockColI",
        "blockRowPtr", "split", "segPtr",
    ];
    for kind in FormatKind::ALL {
        let mut totals: std::collections::BTreeMap<&str, u64> = Default::default();
        for (_, _, m) in matrices {
            let enc = AnyMatrix::encode(kind, m);
            for p in enc.storage().parts {
                *totals.entry(p.name).or_insert(0) += p.bits();
            }
        }
        for name in part_names {
            if let Some(&bits) = totals.get(name) {
                s_csv.row(&[kind.name().to_string(), name.to_string(), bits.to_string()])?;
            }
        }
    }
    s_csv.finish()?;
    // Op-class breakdowns.
    for (metric, fname) in [("ops", "ops"), ("time", "time"), ("energy", "energy")] {
        let mut csv = CsvWriter::create(
            out_dir.join(format!("breakdown_{tag}_{fname}.csv")),
            &["format", "class", "value"],
        )?;
        for kind in FormatKind::ALL {
            let mut by_class = [0.0f64; OpClass::ALL.len()];
            for (_, patches, m) in matrices {
                let trace = trace_matvec(&AnyMatrix::encode(kind, m));
                for (i, class) in OpClass::ALL.iter().enumerate() {
                    let v = match metric {
                        "ops" => trace.ops_of(*class) as f64,
                        "time" => trace.time_of_ns(*class, time),
                        _ => trace.energy_of_pj(*class, energy),
                    };
                    by_class[i] += v * *patches as f64;
                }
            }
            for (i, class) in OpClass::ALL.iter().enumerate() {
                csv.row(&[
                    kind.name().to_string(),
                    class.label().to_string(),
                    format!("{}", by_class[i]),
                ])?;
            }
        }
        csv.finish()?;
    }
    Ok(())
}

/// Fig. 10 — per-layer (H, p₀) scatter of the §V-B zoo plus the feasible-
/// region boundary lines. Writes `figure10.csv`.
pub fn figure10(evals: &[NetworkEval], out_dir: &Path) -> io::Result<()> {
    let mut csv = CsvWriter::create(
        out_dir.join("figure10.csv"),
        &["net", "layer", "H", "p0", "elements"],
    )?;
    for ev in evals {
        for l in &ev.layers {
            csv.row(&[
                ev.net.clone(),
                l.name.clone(),
                format!("{:.4}", l.stats.entropy),
                format!("{:.4}", l.stats.p0),
                format!("{}", l.rows * l.cols),
            ])?;
        }
    }
    csv.finish()?;
    // Boundary lines for the plot.
    let mut b = CsvWriter::create(
        out_dir.join("figure10_boundary.csv"),
        &["p0", "H_min", "H_max"],
    )?;
    for i in 1..100 {
        let p0 = i as f64 / 100.0;
        b.row(&[
            format!("{p0:.2}"),
            format!("{:.4}", min_entropy(p0)),
            format!("{:.4}", max_entropy(p0, 128)),
        ])?;
    }
    b.finish()?;
    Ok(())
}

/// Convenience: rebuild the layer matrices of an evaluated §V-B network for
/// the breakdown figures (same seed ⇒ same matrices as the tables).
pub fn synthesize_vb_matrices(
    net: &str,
    seed: u64,
    scale: usize,
) -> Vec<(String, u64, crate::formats::Dense)> {
    let spec_used = NetworkSpec::by_name(net).unwrap().scaled(scale);
    let target = crate::networks::weights::TargetStats::table_iv(net).unwrap();
    let mats = crate::networks::weights::synthesize_quantized_network(&spec_used, target, seed);
    spec_used
        .layers
        .iter()
        .map(|l| (l.name.clone(), l.patches))
        .zip(mats)
        .map(|((name, patches), m)| (name, patches, m))
        .collect()
}

/// Quick text summary of a figure-4 run (used by the CLI).
pub fn figure4_summary(wins: &[[u32; 3]; 4]) -> String {
    let mut out = String::new();
    for (ci, name) in Criterion4::NAMES.iter().enumerate() {
        out.push_str(&format!(
            "{name:>8}: dense {:>4}  csr {:>4}  proposed {:>4}\n",
            wins[ci][0], wins[ci][1], wins[ci][2]
        ));
    }
    out
}

/// Measured statistics summary of a matrix (CLI `inspect`).
pub fn inspect(m: &crate::formats::Dense) -> String {
    let s = DistStats::measure(m);
    format!(
        "shape {}x{}  K {}  p0 {:.4}  H {:.4} bits  kbar {:.2}  ktilde {:.2}",
        s.m, s.n, s.k, s.p0, s.entropy, s.kbar, s.ktilde
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::eval::EvalConfig;

    fn tmp() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("cer_fig_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn figure1_mode_is_small_and_k_near_128() {
        let d = tmp();
        let (_, freq, k) = figure1(&d, 1).unwrap();
        // Fig. 1: mode frequency ≈ 4.2%, no dominant spike.
        assert!(freq > 0.005 && freq < 0.3, "mode freq {freq}");
        assert!(k > 48 && k <= 128, "K = {k}");
        assert!(d.join("figure1_pmf.csv").exists());
        assert!(d.join("figure1_top15.csv").exists());
    }

    #[test]
    fn figure4_small_grid_produces_expected_regions() {
        let d = tmp();
        let e = EnergyModel::table_i();
        let t = TimeModel::default_model();
        let (feasible, wins) = figure4(&d, 3, 6, 2, 40, 40, 32, &e, &t).unwrap();
        assert!(feasible > 8, "feasible {feasible}");
        // Energy criterion: the proposed formats win somewhere.
        assert!(wins[3][2] > 0, "proposed should win some energy points");
        // Storage: the proposed formats dominate the low-entropy region.
        assert!(wins[0][2] > 0, "proposed should win some storage points");
        // #ops: dense wins the high-entropy/low-sparsity corner (no index
        // loads) — the paper's upper-left blue region.
        assert!(wins[1][0] > 0, "dense should win some ops points");
    }

    #[test]
    fn figure5_ratios_improve_with_n() {
        let d = tmp();
        let e = EnergyModel::table_i();
        let t = TimeModel::default_model();
        let rows = figure5(&d, 5, 4.0, 0.55, 50, &[64, 512, 4096], 2, 128, &e, &t).unwrap();
        // CER (idx 2) storage ratio at n=4096 must exceed ratio at n=64 and
        // beat dense (>1).
        let r64 = rows[0].1[2][0];
        let r4096 = rows[2].1[2][0];
        assert!(r4096 > r64, "CER storage ratio: {r64} → {r4096}");
        assert!(r4096 > 1.0);
        // CER and CSER converge as n → ∞ (§IV corollary).
        let cer = rows[2].1[2][0];
        let cser = rows[2].1[3][0];
        assert!((cer - cser).abs() / cer < 0.1, "CER {cer} vs CSER {cser}");
    }

    #[test]
    fn breakdown_files_written() {
        let d = tmp();
        let mats = synthesize_vb_matrices("densenet", 7, 32);
        let ev = NetworkEval::run_matrices(
            "DenseNet",
            mats.clone(),
            &EvalConfig::fast(32),
        );
        breakdown(
            &ev,
            &mats,
            &d,
            &EnergyModel::table_i(),
            &TimeModel::default_model(),
        )
        .unwrap();
        for f in [
            "breakdown_densenet_storage.csv",
            "breakdown_densenet_ops.csv",
            "breakdown_densenet_time.csv",
            "breakdown_densenet_energy.csv",
        ] {
            assert!(d.join(f).exists(), "{f}");
        }
    }

    #[test]
    fn figure10_scatter_within_feasible_region() {
        let d = tmp();
        let cfg = EvalConfig::fast(24);
        let evals = crate::harness::tables::eval_vb_networks(&cfg);
        figure10(&evals, &d).unwrap();
        for ev in &evals {
            for l in &ev.layers {
                assert!(l.stats.entropy >= min_entropy(l.stats.p0) - 1e-6);
                assert!(l.stats.entropy <= max_entropy(l.stats.p0, 129) + 1e-6);
            }
        }
    }
}
