//! Automatic format selection: evaluate every representation in
//! [`FormatKind::ALL`] under the cost model and pick the argmin for the
//! deployment objective. This is the paper's Fig. 3/4 analysis turned into
//! a runtime policy — dense layers in the high-entropy corner stay dense,
//! compressed layers get CER/CSER, spike-and-slab layers get CSR,
//! block-structured layers get BSR and ternary layers get TNN.
//!
//! Selection is **parallelism-aware**: [`select_format_in`] takes an
//! [`ExecContext`] (the deployment's kernel thread count) and scores the
//! time criterion with [`TimeModel::sharded_ns`] over each candidate
//! format's own nnz-balanced [`crate::exec::ShardPlan`]. Storage, ops and
//! energy are intrinsic to a representation, but wall-clock is a property
//! of the (representation, machine) pair: a CSR layer whose non-zeros pile
//! into one monster row shards poorly — its parallel critical path is
//! still that row — and can lose to dense at 8 threads even though it wins
//! the serial ranking. [`select_format`] is the 1-thread special case and
//! ranks bit-identically to the historical serial selector.

use crate::costmodel::{Criterion4, EnergyModel, ExecContext, TimeModel};
use crate::formats::{Dense, FormatKind};
use crate::kernels::AnyMatrix;

/// What the deployment optimizes for.
///
/// ```
/// use cer::coordinator::{select_format, Objective};
/// use cer::costmodel::{EnergyModel, TimeModel};
///
/// let m = cer::paper_example_matrix();
/// let (energy, time) = (EnergyModel::table_i(), TimeModel::default_model());
/// // The paper's 5x12 example is low-entropy: a run-length format wins
/// // the storage argmin, and the returned criteria prove it.
/// let (kind, crits) = select_format(&m, &energy, &time, Objective::Storage);
/// use cer::formats::FormatKind;
/// assert!(matches!(kind, FormatKind::Cer | FormatKind::Cser));
/// let dense_bits = crits[0].storage_bits; // criteria in FormatKind::ALL order
/// let winner_bits = crits[FormatKind::ALL.iter().position(|&k| k == kind).unwrap()].storage_bits;
/// assert!(winner_bits < dense_bits);
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Objective {
    /// Minimize modeled energy per inference (the paper's headline metric).
    Energy,
    /// Minimize modeled time per inference.
    Time,
    /// Minimize elementary operations.
    Ops,
    /// Minimize storage footprint.
    Storage,
    /// Weighted blend (weights over [storage, ops, time, energy],
    /// normalized by the dense baseline so units are comparable).
    Weighted([f64; 4]),
}

impl Objective {
    fn score(&self, c: &Criterion4, dense: &Criterion4) -> f64 {
        match self {
            Objective::Energy => c.energy_pj,
            Objective::Time => c.time_ns,
            Objective::Ops => c.ops as f64,
            Objective::Storage => c.storage_bits as f64,
            Objective::Weighted(w) => {
                let norm = |v: f64, b: f64| if b > 0.0 { v / b } else { v };
                w[0] * norm(c.storage_bits as f64, dense.storage_bits as f64)
                    + w[1] * norm(c.ops as f64, dense.ops as f64)
                    + w[2] * norm(c.time_ns, dense.time_ns)
                    + w[3] * norm(c.energy_pj, dense.energy_pj)
            }
        }
    }
}

/// Index of the dense baseline in [`FormatKind::ALL`] — looked up by kind
/// rather than assumed to be slot 0, so a reorder of `ALL` cannot silently
/// corrupt [`Objective::Weighted`] normalization (which divides every
/// candidate's criteria by the *dense* ones).
///
/// The position check is a *hard* assertion (not `debug_assert`): several
/// callers — the harness tables, the engine, the bench's selection audit —
/// index the dense baseline at slot 0 of the returned criteria array, so a
/// reordered `ALL` in a release build would silently mis-normalize every
/// `Weighted` score. Failing loudly at the first selection is the correct
/// behavior until those callers look the slot up by kind too.
fn dense_index() -> usize {
    let i = FormatKind::ALL
        .iter()
        .position(|&k| k == FormatKind::Dense)
        .expect("FormatKind::ALL must contain Dense");
    assert_eq!(
        i, 0,
        "callers (harness tables, engine) index the dense baseline at 0; \
         keep Dense first in FormatKind::ALL or update them"
    );
    i
}

/// Evaluate all formats for `m` under the **serial** context and return
/// (winner, per-format criteria in [`FormatKind::ALL`] order).
///
/// Equivalent to [`select_format_in`] with [`ExecContext::SERIAL`]; the
/// ranking is bit-identical to the historical thread-unaware selector.
pub fn select_format(
    m: &Dense,
    energy: &EnergyModel,
    time: &TimeModel,
    objective: Objective,
) -> (FormatKind, [Criterion4; FormatKind::COUNT]) {
    select_format_in(m, energy, time, objective, ExecContext::SERIAL)
}

/// Evaluate all formats for `m` as deployed under `ctx` and return
/// (winner, per-format criteria in [`FormatKind::ALL`] order).
///
/// The time criterion of every candidate is computed with
/// [`TimeModel::sharded_ns`] over that candidate's **own**
/// [`crate::exec::ShardPlan`] at `ctx.threads`, so the
/// [`Objective::Time`] (and [`Objective::Weighted`]) argmin is a function
/// of the thread count. Ties keep the earlier [`FormatKind::ALL`] entry,
/// exactly like the serial selector.
///
/// ```
/// use cer::coordinator::{select_format_in, Objective};
/// use cer::costmodel::{EnergyModel, ExecContext, TimeModel};
/// use cer::stats::synth::spike_and_slab;
///
/// let (energy, time) = (EnergyModel::table_i(), TimeModel::default_model());
/// // One fully-dense spike row + 7 nearly-empty slab rows: serially the
/// // sparse formats win on time, but no shard plan can split the spike,
/// // while dense's uniform rows shard 8 ways — the winner flips.
/// let m = spike_and_slab(8, 255, 2);
/// let (at1, _) = select_format_in(&m, &energy, &time, Objective::Time, ExecContext::SERIAL);
/// let (at8, _) = select_format_in(&m, &energy, &time, Objective::Time,
///                                 ExecContext::with_threads(8));
/// assert_ne!(at1, at8);
/// assert_eq!(at8, cer::formats::FormatKind::Dense);
/// ```
pub fn select_format_in(
    m: &Dense,
    energy: &EnergyModel,
    time: &TimeModel,
    objective: Objective,
    ctx: ExecContext,
) -> (FormatKind, [Criterion4; FormatKind::COUNT]) {
    let crits: Vec<Criterion4> = FormatKind::ALL
        .iter()
        .map(|&k| Criterion4::evaluate_in(&AnyMatrix::encode(k, m), energy, time, ctx))
        .collect();
    let dense = crits[dense_index()];
    let mut best = 0usize;
    let mut best_score = objective.score(&crits[0], &dense);
    for (i, c) in crits.iter().enumerate().skip(1) {
        let s = objective.score(c, &dense);
        if s < best_score {
            best = i;
            best_score = s;
        }
    }
    (FormatKind::ALL[best], std::array::from_fn(|i| crits[i]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::synth::{spike_and_slab, PlanePoint};
    use crate::util::Rng;

    fn models() -> (EnergyModel, TimeModel) {
        (EnergyModel::table_i(), TimeModel::default_model())
    }

    #[test]
    fn low_entropy_layer_selects_proposed_format() {
        let (e, t) = models();
        let p = PlanePoint::synthesize(1.5, 0.6, 32).unwrap();
        let m = p.sample_matrix(100, 400, &mut Rng::new(1));
        let (kind, _) = select_format(&m, &e, &t, Objective::Energy);
        assert!(
            matches!(kind, FormatKind::Cer | FormatKind::Cser),
            "picked {kind:?}"
        );
        let (kind, _) = select_format(&m, &e, &t, Objective::Storage);
        assert!(matches!(kind, FormatKind::Cer | FormatKind::Cser));
    }

    #[test]
    fn high_entropy_layer_keeps_dense_for_ops() {
        let (e, t) = models();
        // Near-uniform over 128 values: the dense-wins corner for #ops.
        let p = PlanePoint::synthesize(6.9, 0.009, 128).unwrap();
        let m = p.sample_matrix(60, 60, &mut Rng::new(2));
        let (kind, _) = select_format(&m, &e, &t, Objective::Ops);
        assert_eq!(kind, FormatKind::Dense);
    }

    #[test]
    fn selector_is_argmin_for_every_objective() {
        let (e, t) = models();
        let p = PlanePoint::synthesize(3.0, 0.4, 64).unwrap();
        let m = p.sample_matrix(80, 200, &mut Rng::new(3));
        for obj in [
            Objective::Energy,
            Objective::Time,
            Objective::Ops,
            Objective::Storage,
        ] {
            let (kind, crits) = select_format(&m, &e, &t, obj);
            let winner_idx = FormatKind::ALL.iter().position(|&k| k == kind).unwrap();
            let dense = crits[0];
            for (i, c) in crits.iter().enumerate() {
                assert!(
                    obj.score(&crits[winner_idx], &dense) <= obj.score(c, &dense) + 1e-9,
                    "{obj:?}: {kind:?} not argmin vs format {i}"
                );
            }
        }
    }

    #[test]
    fn weighted_objective_blends() {
        let (e, t) = models();
        let p = PlanePoint::synthesize(2.0, 0.5, 32).unwrap();
        let m = p.sample_matrix(50, 300, &mut Rng::new(4));
        let (kind, _) = select_format(&m, &e, &t, Objective::Weighted([1.0, 0.0, 0.0, 1.0]));
        assert!(matches!(kind, FormatKind::Cer | FormatKind::Cser));
    }

    /// Regression: `select_format` (= `select_format_in` at 1 thread) must
    /// reproduce the historical serial ranking bit for bit — same
    /// criteria from the serial `Criterion4::evaluate`, same
    /// first-index-wins argmin seeded at slot 0 with the dense baseline
    /// for `Weighted` normalization.
    #[test]
    fn one_thread_selection_is_bit_identical_to_serial_ranking() {
        let (e, t) = models();
        let mut rng = Rng::new(7);
        let objectives = [
            Objective::Energy,
            Objective::Time,
            Objective::Ops,
            Objective::Storage,
            Objective::Weighted([0.25, 0.25, 0.25, 0.25]),
            Objective::Weighted([0.0, 0.0, 1.0, 0.0]),
        ];
        let mut cases: Vec<Dense> = vec![spike_and_slab(8, 255, 2)];
        for (h, p0, k) in [(1.5, 0.6, 32), (3.0, 0.4, 64), (6.9, 0.009, 128)] {
            let p = PlanePoint::synthesize(h, p0, k).unwrap();
            cases.push(p.sample_matrix(40, 120, &mut rng));
        }
        for m in &cases {
            // The pre-thread-aware selector, reproduced verbatim.
            let crits_old: Vec<Criterion4> = FormatKind::ALL
                .iter()
                .map(|&k| Criterion4::evaluate(&AnyMatrix::encode(k, m), &e, &t))
                .collect();
            for obj in objectives {
                let dense = crits_old[0];
                let mut best = 0usize;
                let mut best_score = obj.score(&crits_old[0], &dense);
                for (i, c) in crits_old.iter().enumerate().skip(1) {
                    let s = obj.score(c, &dense);
                    if s < best_score {
                        best = i;
                        best_score = s;
                    }
                }
                let (kind, crits) = select_format(m, &e, &t, obj);
                assert_eq!(kind, FormatKind::ALL[best], "{obj:?}: winner drifted");
                for (a, b) in crits.iter().zip(&crits_old) {
                    assert_eq!(a, b, "{obj:?}: criteria drifted");
                }
            }
        }
    }

    /// The tentpole property: the time argmin is a function of the thread
    /// count. On the spike-and-slab matrix the sparse formats win
    /// serially, but their shard plans are capped by the spike row while
    /// dense shards its uniform rows 8 ways — the winner flips to dense.
    #[test]
    fn time_selection_is_thread_sensitive_on_spike_and_slab() {
        let (e, t) = models();
        let m = spike_and_slab(8, 255, 2);
        let (at1, crits1) = select_format(&m, &e, &t, Objective::Time);
        let (at8, crits8) =
            select_format_in(&m, &e, &t, Objective::Time, ExecContext::with_threads(8));
        assert_ne!(at1, at8, "winner must change with the thread count");
        assert_eq!(at1, FormatKind::Csr, "serial winner: touch only the nnz");
        assert_eq!(at8, FormatKind::Dense, "8-thread winner: uniform shards");
        // The flip is *justified* by the plan-aware estimates: at 8
        // threads dense's modeled time undercuts every sparse format even
        // though all of them beat it serially.
        for i in 1..FormatKind::COUNT {
            assert!(crits1[i].time_ns < crits1[0].time_ns, "serial: sparse wins");
            assert!(crits8[0].time_ns < crits8[i].time_ns, "8t: dense wins");
        }
        // Intrinsic criteria are context-independent.
        for (a, b) in crits1.iter().zip(&crits8) {
            assert_eq!(a.storage_bits, b.storage_bits);
            assert_eq!(a.ops, b.ops);
            assert_eq!(a.energy_pj, b.energy_pj);
        }
    }

    /// Sweep the whole thread ladder on the spike matrix: the flip to
    /// dense happens once the lane count outruns what the spike-capped
    /// sparse plans can use, and selection is monotone in between (no
    /// flapping back to a sparse format afterwards).
    #[test]
    fn spike_and_slab_flip_point_is_stable() {
        let (e, t) = models();
        let m = spike_and_slab(8, 255, 2);
        let mut winners = Vec::new();
        for threads in [1usize, 2, 4, 8] {
            let (k, _) =
                select_format_in(&m, &e, &t, Objective::Time, ExecContext::with_threads(threads));
            winners.push(k);
        }
        assert_eq!(winners[0], FormatKind::Csr);
        assert_eq!(*winners.last().unwrap(), FormatKind::Dense);
        let flip = winners.iter().position(|&k| k == FormatKind::Dense).unwrap();
        assert!(
            winners[flip..].iter().all(|&k| k == FormatKind::Dense),
            "selection must not flap after the flip: {winners:?}"
        );
    }

    /// Objectives that ignore time must be thread-invariant.
    #[test]
    fn intrinsic_objectives_ignore_threads() {
        let (e, t) = models();
        let p = PlanePoint::synthesize(2.5, 0.5, 32).unwrap();
        let m = p.sample_matrix(60, 200, &mut Rng::new(9));
        for obj in [Objective::Energy, Objective::Ops, Objective::Storage] {
            let (at1, _) = select_format(&m, &e, &t, obj);
            for threads in [2usize, 4, 8, 16] {
                let (atn, _) =
                    select_format_in(&m, &e, &t, obj, ExecContext::with_threads(threads));
                assert_eq!(at1, atn, "{obj:?} must not depend on threads");
            }
        }
    }
}
