//! Readers for the build-time artifacts exported by `python/compile/train.py`
//! (weights, test set, manifest) — see that module's docstring for the file
//! formats. Everything is raw little-endian binary + a key/value manifest,
//! so no serde dependency is needed.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::formats::Dense;

/// One loaded MLP layer.
#[derive(Clone, Debug)]
pub struct MlpLayer {
    /// Trained float weights (out × in).
    pub weights: Dense,
    /// Compressed (pruned + clustered) weights, same shape.
    pub quantized: Dense,
    /// Bias (out).
    pub bias: Vec<f32>,
}

/// The full artifact bundle of the e2e model.
#[derive(Clone, Debug)]
pub struct MlpArtifacts {
    pub layers: Vec<MlpLayer>,
    /// Test inputs, row-major (n_test × in_dim).
    pub test_x: Vec<f32>,
    /// Test labels.
    pub test_y: Vec<i32>,
    pub n_test: usize,
    /// Static batch size the HLO artifacts were lowered for.
    pub batch: usize,
    /// Accuracies recorded at build time (float, compressed).
    pub accuracy_float: f64,
    pub accuracy_quant: f64,
    /// Paths of the HLO artifacts.
    pub dense_hlo: PathBuf,
    pub cser_hlo: PathBuf,
}

fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_i32(path: &Path) -> Result<Vec<i32>> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

impl MlpArtifacts {
    /// Load from `artifacts/` (expects the layout written by aot.py).
    pub fn load(artifacts_dir: &Path) -> Result<MlpArtifacts> {
        let mlp = artifacts_dir.join("mlp");
        let manifest = fs::read_to_string(mlp.join("manifest.txt"))
            .with_context(|| format!("{}/manifest.txt — run `make artifacts`", mlp.display()))?;
        let mut kv = std::collections::HashMap::new();
        let mut layer_dims: Vec<(usize, usize)> = Vec::new();
        for line in manifest.lines() {
            let mut it = line.split_whitespace();
            let Some(key) = it.next() else { continue };
            let rest: Vec<&str> = it.collect();
            if let Some(idx) = key.strip_prefix("layer") {
                if let Ok(i) = idx.parse::<usize>() {
                    if rest.len() == 2 {
                        let out: usize = rest[0].parse()?;
                        let inp: usize = rest[1].parse()?;
                        if layer_dims.len() <= i {
                            layer_dims.resize(i + 1, (0, 0));
                        }
                        layer_dims[i] = (out, inp);
                        continue;
                    }
                }
            }
            kv.insert(key.to_string(), rest.join(" "));
        }
        let n_layers: usize = kv
            .get("layers")
            .context("manifest missing 'layers'")?
            .parse()?;
        if layer_dims.len() != n_layers {
            bail!("manifest: {} layer dims for {} layers", layer_dims.len(), n_layers);
        }
        let mut layers = Vec::with_capacity(n_layers);
        for (i, &(out, inp)) in layer_dims.iter().enumerate() {
            let w = read_f32(&mlp.join(format!("fc{i}_w.f32")))?;
            let qw = read_f32(&mlp.join(format!("fcq{i}_w.f32")))?;
            let b = read_f32(&mlp.join(format!("fc{i}_b.f32")))?;
            if w.len() != out * inp || qw.len() != out * inp || b.len() != out {
                bail!("layer {i}: file sizes do not match manifest dims {out}x{inp}");
            }
            layers.push(MlpLayer {
                weights: Dense::from_vec(out, inp, w),
                quantized: Dense::from_vec(out, inp, qw),
                bias: b,
            });
        }
        let n_test: usize = kv.get("test_n").context("manifest missing test_n")?.parse()?;
        let test_x = read_f32(&mlp.join("test_x.f32"))?;
        let test_y = read_i32(&mlp.join("test_y.i32"))?;
        let in_dim = layer_dims[0].1;
        if test_x.len() != n_test * in_dim || test_y.len() != n_test {
            bail!("test set sizes do not match manifest");
        }
        Ok(MlpArtifacts {
            layers,
            test_x,
            test_y,
            n_test,
            batch: kv.get("batch").context("manifest missing batch")?.parse()?,
            accuracy_float: kv
                .get("accuracy_float")
                .context("missing accuracy_float")?
                .parse()?,
            accuracy_quant: kv
                .get("accuracy_quant")
                .context("missing accuracy_quant")?
                .parse()?,
            dense_hlo: artifacts_dir.join("model_dense.hlo.txt"),
            cser_hlo: artifacts_dir.join("model_cser.hlo.txt"),
        })
    }

    /// Input dimensionality.
    pub fn in_dim(&self) -> usize {
        self.layers[0].weights.cols()
    }

    /// One test batch (padded with zeros to `batch` if needed). Returns
    /// (x row-major batch×in_dim, labels, valid_count).
    pub fn test_batch(&self, start: usize) -> (Vec<f32>, Vec<i32>, usize) {
        let in_dim = self.in_dim();
        let end = (start + self.batch).min(self.n_test);
        let valid = end - start;
        let mut x = vec![0.0f32; self.batch * in_dim];
        x[..valid * in_dim]
            .copy_from_slice(&self.test_x[start * in_dim..end * in_dim]);
        let y = self.test_y[start..end].to_vec();
        (x, y, valid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Write a minimal synthetic artifact bundle and read it back.
    #[test]
    fn roundtrip_synthetic_bundle() {
        let dir = std::env::temp_dir().join(format!("cer_art_{}", std::process::id()));
        let mlp = dir.join("mlp");
        fs::create_dir_all(&mlp).unwrap();
        let dims = [(3usize, 4usize), (2, 3)];
        for (i, &(out, inp)) in dims.iter().enumerate() {
            let w: Vec<f32> = (0..out * inp).map(|v| v as f32).collect();
            let b: Vec<f32> = vec![0.5; out];
            for (suffix, data) in [("w", &w), ("b", &b)] {
                let mut f =
                    fs::File::create(mlp.join(format!("fc{i}_{suffix}.f32"))).unwrap();
                for v in data.iter() {
                    f.write_all(&v.to_le_bytes()).unwrap();
                }
            }
            let mut f = fs::File::create(mlp.join(format!("fcq{i}_w.f32"))).unwrap();
            for v in &w {
                f.write_all(&(v * 0.5).to_le_bytes()).unwrap();
            }
        }
        let n_test = 5;
        let mut f = fs::File::create(mlp.join("test_x.f32")).unwrap();
        for v in 0..n_test * 4 {
            f.write_all(&(v as f32).to_le_bytes()).unwrap();
        }
        let mut f = fs::File::create(mlp.join("test_y.i32")).unwrap();
        for v in 0..n_test {
            f.write_all(&(v as i32 % 2).to_le_bytes()).unwrap();
        }
        fs::write(
            mlp.join("manifest.txt"),
            "layers 2\nlayer0 3 4\nlayer1 2 3\ntest_n 5\nbatch 2\naccuracy_float 0.99\naccuracy_quant 0.97\nseed 1\n",
        )
        .unwrap();

        let art = MlpArtifacts::load(&dir).unwrap();
        assert_eq!(art.layers.len(), 2);
        assert_eq!(art.layers[0].weights.rows(), 3);
        assert_eq!(art.layers[0].weights.cols(), 4);
        assert_eq!(art.layers[0].quantized.get(0, 1), 0.5);
        assert_eq!(art.n_test, 5);
        assert!((art.accuracy_float - 0.99).abs() < 1e-9);
        // Batch padding.
        let (x, y, valid) = art.test_batch(4);
        assert_eq!(valid, 1);
        assert_eq!(y.len(), 1);
        assert_eq!(x.len(), 2 * 4);
        assert_eq!(&x[4..], &[0.0; 4]); // padded row
    }

    #[test]
    fn missing_manifest_is_actionable_error() {
        let err = MlpArtifacts::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
