//! Index-width machinery.
//!
//! The paper (§V) stores index and pointer arrays "compressed ... to their
//! minimum required bit-sizes, where we restricted them to be either 8, 16
//! or 32 bits". The column-index array is the one that dominates both
//! storage and the dot-product inner loop, so it is kept *physically* at the
//! minimal width ([`ColIndices`]) and every kernel is monomorphized over the
//! element type. Pointer arrays (rowPtr, ΩPtr, ΩI) are touched only O(m·k̄)
//! times per product — they are held as `u32` in memory for simplicity and
//! their *accounted* width ([`IndexWidth::minimal`] of their max value) is
//! what enters the storage/energy model, exactly as in the paper's
//! analytical accounting.

use super::storage::Storage;

/// One of the three admissible index bit-widths.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum IndexWidth {
    U8,
    U16,
    U32,
}

impl IndexWidth {
    /// Minimal admissible width able to represent `max_value`.
    pub fn minimal(max_value: usize) -> IndexWidth {
        if max_value <= u8::MAX as usize {
            IndexWidth::U8
        } else if max_value <= u16::MAX as usize {
            IndexWidth::U16
        } else {
            assert!(
                max_value <= u32::MAX as usize,
                "index value {max_value} exceeds u32"
            );
            IndexWidth::U32
        }
    }

    /// Width in bits (the paper's b_I).
    pub fn bits(self) -> u32 {
        match self {
            IndexWidth::U8 => 8,
            IndexWidth::U16 => 16,
            IndexWidth::U32 => 32,
        }
    }

    /// Width in bytes.
    pub fn bytes(self) -> usize {
        self.bits() as usize / 8
    }

    /// Stable one-byte wire tag used by the `.cerpack` container.
    pub fn tag(self) -> u8 {
        match self {
            IndexWidth::U8 => 0,
            IndexWidth::U16 => 1,
            IndexWidth::U32 => 2,
        }
    }

    /// Inverse of [`IndexWidth::tag`].
    pub fn from_tag(tag: u8) -> Option<IndexWidth> {
        match tag {
            0 => Some(IndexWidth::U8),
            1 => Some(IndexWidth::U16),
            2 => Some(IndexWidth::U32),
            _ => None,
        }
    }
}

/// Trait over the physical column-index element types.
pub trait Idx: Copy + Send + Sync + 'static {
    const BITS: u32;
    fn to_usize(self) -> usize;
    fn from_usize(v: usize) -> Self;
}

macro_rules! impl_idx {
    ($t:ty, $bits:expr) => {
        impl Idx for $t {
            const BITS: u32 = $bits;
            #[inline(always)]
            fn to_usize(self) -> usize {
                self as usize
            }
            #[inline(always)]
            fn from_usize(v: usize) -> Self {
                debug_assert!(v <= <$t>::MAX as usize);
                v as $t
            }
        }
    };
}
impl_idx!(u8, 8);
impl_idx!(u16, 16);
impl_idx!(u32, 32);

/// A column-index array physically stored at its minimal width. The
/// elements live in a [`Storage`] — owned after `from_dense` conversion,
/// a zero-copy view into the mapped pack after a `Pack::from_map` cold
/// start.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ColIndices {
    U8(Storage<u8>),
    U16(Storage<u16>),
    U32(Storage<u32>),
}

impl ColIndices {
    /// Pack `indices` (each `< n_cols`) at the minimal width for `n_cols`.
    ///
    /// The width is chosen from the matrix column count (not the max index
    /// present) so that matrices with identical shapes always get identical
    /// layouts — this matches the paper's accounting, where b_I is a
    /// property of the matrix dimension.
    pub fn pack(indices: &[usize], n_cols: usize) -> ColIndices {
        match IndexWidth::minimal(n_cols.saturating_sub(1)) {
            IndexWidth::U8 => {
                ColIndices::U8(indices.iter().map(|&i| i as u8).collect::<Vec<_>>().into())
            }
            IndexWidth::U16 => {
                ColIndices::U16(indices.iter().map(|&i| i as u16).collect::<Vec<_>>().into())
            }
            IndexWidth::U32 => {
                ColIndices::U32(indices.iter().map(|&i| i as u32).collect::<Vec<_>>().into())
            }
        }
    }

    pub fn width(&self) -> IndexWidth {
        match self {
            ColIndices::U8(_) => IndexWidth::U8,
            ColIndices::U16(_) => IndexWidth::U16,
            ColIndices::U32(_) => IndexWidth::U32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            ColIndices::U8(v) => v.len(),
            ColIndices::U16(v) => v.len(),
            ColIndices::U32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Storage footprint in bits.
    pub fn bits(&self) -> u64 {
        self.len() as u64 * self.width().bits() as u64
    }

    /// Random access (dispatching; use [`crate::with_col_indices!`] in hot
    /// loops instead).
    #[inline]
    pub fn get(&self, i: usize) -> usize {
        match self {
            ColIndices::U8(v) => v[i] as usize,
            ColIndices::U16(v) => v[i] as usize,
            ColIndices::U32(v) => v[i] as usize,
        }
    }

    /// Copy out as `usize` values (slow path, tests/validation only).
    pub fn to_vec(&self) -> Vec<usize> {
        (0..self.len()).map(|i| self.get(i)).collect()
    }

    /// Append the raw little-endian element bytes (`.cerpack` codec). The
    /// physical width is *not* written here — callers store
    /// [`IndexWidth::tag`] in their own headers.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            ColIndices::U8(v) => out.extend_from_slice(v),
            ColIndices::U16(v) => {
                out.reserve(v.len() * 2);
                for &x in v.iter() {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            ColIndices::U32(v) => {
                out.reserve(v.len() * 4);
                for &x in v.iter() {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }

    /// Whether the index array is a zero-copy view into a mapped pack.
    pub fn is_mapped(&self) -> bool {
        match self {
            ColIndices::U8(v) => v.is_mapped(),
            ColIndices::U16(v) => v.is_mapped(),
            ColIndices::U32(v) => v.is_mapped(),
        }
    }

    /// Byte footprint of the element array (both backings).
    pub fn byte_len(&self) -> u64 {
        match self {
            ColIndices::U8(v) => v.byte_len(),
            ColIndices::U16(v) => v.byte_len(),
            ColIndices::U32(v) => v.byte_len(),
        }
    }

    /// Decode `count` elements stored at `width` out of `cur` into owned
    /// storage, validating every index against `n_cols`. (The zero-copy
    /// path is [`crate::pack::wire::ArrayLoader::col_indices`].)
    pub fn decode_from(
        width: IndexWidth,
        count: usize,
        n_cols: usize,
        cur: &mut crate::pack::wire::Cursor,
    ) -> Result<ColIndices, crate::pack::PackError> {
        crate::pack::wire::ArrayLoader::owned().col_indices(cur, width, count, n_cols)
    }
}

/// Dispatch a generic block over the physical index type of a
/// [`ColIndices`]. `$slice` binds to the typed `&[T]` slice (whatever the
/// backing — owned or mapped — the kernels see a plain slice).
#[macro_export]
macro_rules! with_col_indices {
    ($ci:expr, $slice:ident => $body:expr) => {
        match $ci {
            $crate::formats::ColIndices::U8(__cer_ci_storage) => {
                let $slice = __cer_ci_storage.as_slice();
                $body
            }
            $crate::formats::ColIndices::U16(__cer_ci_storage) => {
                let $slice = __cer_ci_storage.as_slice();
                $body
            }
            $crate::formats::ColIndices::U32(__cer_ci_storage) => {
                let $slice = __cer_ci_storage.as_slice();
                $body
            }
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_width_boundaries() {
        assert_eq!(IndexWidth::minimal(0), IndexWidth::U8);
        assert_eq!(IndexWidth::minimal(255), IndexWidth::U8);
        assert_eq!(IndexWidth::minimal(256), IndexWidth::U16);
        assert_eq!(IndexWidth::minimal(65_535), IndexWidth::U16);
        assert_eq!(IndexWidth::minimal(65_536), IndexWidth::U32);
    }

    #[test]
    fn pack_uses_column_count_not_max_present() {
        // Even if all indices fit in u8, a 70k-column matrix needs u32.
        let ci = ColIndices::pack(&[1, 2, 3], 70_000);
        assert_eq!(ci.width(), IndexWidth::U32);
        let ci = ColIndices::pack(&[1, 2, 3], 200);
        assert_eq!(ci.width(), IndexWidth::U8);
    }

    #[test]
    fn roundtrip_all_widths() {
        for n in [10usize, 1_000, 100_000] {
            let idx: Vec<usize> = (0..9).map(|i| i * (n / 9)).collect();
            let ci = ColIndices::pack(&idx, n);
            assert_eq!(ci.to_vec(), idx);
            assert_eq!(ci.len(), idx.len());
        }
    }

    #[test]
    fn bits_accounting() {
        let ci = ColIndices::pack(&[0, 1, 2, 3], 1_000);
        assert_eq!(ci.width(), IndexWidth::U16);
        assert_eq!(ci.bits(), 4 * 16);
    }

    #[test]
    fn macro_dispatch() {
        let ci = ColIndices::pack(&[5, 6], 100);
        let total: usize = with_col_indices!(&ci, s => s.iter().map(|&v| v as usize).sum());
        assert_eq!(total, 11);
    }
}
