//! The paper's §IV cost model.
//!
//! A dot-product algorithm is modeled as a computational graph of four
//! elementary operations — *sum*, *mul*, *read*, *write* — each with a cost
//! that depends on the operand bit-size and, for memory operations, on the
//! size of the array the operand lives in (Table I). This module provides:
//!
//! * [`opcount`] — [`OpTrace`]: exact elementary-operation counts of a dot
//!   product, keyed by operation class / bit-width / memory tier.
//! * [`trace`] — walks each representation and produces its `OpTrace`
//!   (the "counted kernels": same accounting as the paper's worked example
//!   in §III-B).
//! * [`energy`] — [`EnergyModel`]: Table I (45nm CMOS) energy per op.
//! * [`time`] — [`TimeModel`]: per-op latencies (static defaults for
//!   determinism + on-host calibration).
//! * [`analytic`] — the closed-form storage/energy equations (1)–(12) and
//!   the Theorem 1/2 / Corollary 2.1 bounds.
//! * [`calibrate`] — `repro calibrate`: cache-ruined per-kernel
//!   micro-benchmarks fitting measured-vs-modeled slopes per (format,
//!   backend) plus the pool dispatch overhead, round-tripped through
//!   `calibration.json`.

pub mod analytic;
pub mod calibrate;
pub mod energy;
pub mod opcount;
pub mod time;
pub mod trace;

pub use analytic::DistStats;
pub use calibrate::{run_calibration, BackendFit, CalRow, Calibration};
pub use energy::{EnergyModel, MemTier};
pub use opcount::{BaseOp, OpClass, OpTrace};
pub use time::TimeModel;
pub use trace::{trace_matvec, Criterion4};

use crate::formats::FormatKind;

/// Re-export for harness ergonomics.
pub type Format = FormatKind;

/// The execution context a representation is evaluated against: how many
/// kernel lanes the deployment will actually run.
///
/// Storage, ops and energy are intrinsic to a representation, but *time*
/// is a property of the (representation, machine) pair: with more than one
/// lane the critical path of a layer product is its heaviest
/// [`crate::exec::ShardPlan`] shard, not the serial op sum. Evaluating a
/// format under an `ExecContext` lets the selector rank candidates by what
/// the hardware will really execute — a CSR layer whose non-zeros pile
/// into one monster row shards poorly and can lose to dense at 8 threads
/// even though it wins serially.
///
/// ```
/// use cer::costmodel::ExecContext;
///
/// assert_eq!(ExecContext::SERIAL.threads, 1);
/// assert_eq!(ExecContext::with_threads(8).threads, 8);
/// // Degenerate requests clamp to the serial context.
/// assert_eq!(ExecContext::with_threads(0), ExecContext::SERIAL);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecContext {
    /// Total execution lanes (1 = the serial kernels, matching the
    /// engine's [`crate::coordinator::Engine::threads`] count).
    pub threads: usize,
}

impl ExecContext {
    /// The 1-thread context: modeled time is the plain serial op sum, so
    /// every evaluation under `SERIAL` is bit-identical to the historical
    /// (pre-thread-aware) cost model.
    pub const SERIAL: ExecContext = ExecContext { threads: 1 };

    /// Context for `threads`-way execution (`0` and `1` both mean serial).
    pub fn with_threads(threads: usize) -> ExecContext {
        ExecContext {
            threads: threads.max(1),
        }
    }
}

impl Default for ExecContext {
    fn default() -> Self {
        ExecContext::SERIAL
    }
}
