//! Automatic format selection: evaluate all four representations of a
//! layer under the cost model and pick the argmin for the deployment
//! objective. This is the paper's Fig. 3/4 analysis turned into a runtime
//! policy — dense layers in the high-entropy corner stay dense, compressed
//! layers get CER/CSER, spike-and-slab layers get CSR.

use crate::costmodel::{Criterion4, EnergyModel, TimeModel};
use crate::formats::{Dense, FormatKind};
use crate::kernels::AnyMatrix;

/// What the deployment optimizes for.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Objective {
    /// Minimize modeled energy per inference (the paper's headline metric).
    Energy,
    /// Minimize modeled time per inference.
    Time,
    /// Minimize elementary operations.
    Ops,
    /// Minimize storage footprint.
    Storage,
    /// Weighted blend (weights over [storage, ops, time, energy],
    /// normalized by the dense baseline so units are comparable).
    Weighted([f64; 4]),
}

impl Objective {
    fn score(&self, c: &Criterion4, dense: &Criterion4) -> f64 {
        match self {
            Objective::Energy => c.energy_pj,
            Objective::Time => c.time_ns,
            Objective::Ops => c.ops as f64,
            Objective::Storage => c.storage_bits as f64,
            Objective::Weighted(w) => {
                let norm = |v: f64, b: f64| if b > 0.0 { v / b } else { v };
                w[0] * norm(c.storage_bits as f64, dense.storage_bits as f64)
                    + w[1] * norm(c.ops as f64, dense.ops as f64)
                    + w[2] * norm(c.time_ns, dense.time_ns)
                    + w[3] * norm(c.energy_pj, dense.energy_pj)
            }
        }
    }
}

/// Evaluate all formats for `m` and return (winner, per-format criteria in
/// [`FormatKind::ALL`] order).
pub fn select_format(
    m: &Dense,
    energy: &EnergyModel,
    time: &TimeModel,
    objective: Objective,
) -> (FormatKind, [Criterion4; 4]) {
    let crits: Vec<Criterion4> = FormatKind::ALL
        .iter()
        .map(|&k| Criterion4::evaluate(&AnyMatrix::encode(k, m), energy, time))
        .collect();
    let dense = crits[0];
    let mut best = 0usize;
    let mut best_score = objective.score(&crits[0], &dense);
    for (i, c) in crits.iter().enumerate().skip(1) {
        let s = objective.score(c, &dense);
        if s < best_score {
            best = i;
            best_score = s;
        }
    }
    (
        FormatKind::ALL[best],
        [crits[0], crits[1], crits[2], crits[3]],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::synth::PlanePoint;
    use crate::util::Rng;

    fn models() -> (EnergyModel, TimeModel) {
        (EnergyModel::table_i(), TimeModel::default_model())
    }

    #[test]
    fn low_entropy_layer_selects_proposed_format() {
        let (e, t) = models();
        let p = PlanePoint::synthesize(1.5, 0.6, 32).unwrap();
        let m = p.sample_matrix(100, 400, &mut Rng::new(1));
        let (kind, _) = select_format(&m, &e, &t, Objective::Energy);
        assert!(
            matches!(kind, FormatKind::Cer | FormatKind::Cser),
            "picked {kind:?}"
        );
        let (kind, _) = select_format(&m, &e, &t, Objective::Storage);
        assert!(matches!(kind, FormatKind::Cer | FormatKind::Cser));
    }

    #[test]
    fn high_entropy_layer_keeps_dense_for_ops() {
        let (e, t) = models();
        // Near-uniform over 128 values: the dense-wins corner for #ops.
        let p = PlanePoint::synthesize(6.9, 0.009, 128).unwrap();
        let m = p.sample_matrix(60, 60, &mut Rng::new(2));
        let (kind, _) = select_format(&m, &e, &t, Objective::Ops);
        assert_eq!(kind, FormatKind::Dense);
    }

    #[test]
    fn selector_is_argmin_for_every_objective() {
        let (e, t) = models();
        let p = PlanePoint::synthesize(3.0, 0.4, 64).unwrap();
        let m = p.sample_matrix(80, 200, &mut Rng::new(3));
        for obj in [
            Objective::Energy,
            Objective::Time,
            Objective::Ops,
            Objective::Storage,
        ] {
            let (kind, crits) = select_format(&m, &e, &t, obj);
            let winner_idx = FormatKind::ALL.iter().position(|&k| k == kind).unwrap();
            let dense = crits[0];
            for (i, c) in crits.iter().enumerate() {
                assert!(
                    obj.score(&crits[winner_idx], &dense) <= obj.score(c, &dense) + 1e-9,
                    "{obj:?}: {kind:?} not argmin vs format {i}"
                );
            }
        }
    }

    #[test]
    fn weighted_objective_blends() {
        let (e, t) = models();
        let p = PlanePoint::synthesize(2.0, 0.5, 32).unwrap();
        let m = p.sample_matrix(50, 300, &mut Rng::new(4));
        let (kind, _) = select_format(&m, &e, &t, Objective::Weighted([1.0, 0.0, 0.0, 1.0]));
        assert!(matches!(kind, FormatKind::Cer | FormatKind::Cser));
    }
}
