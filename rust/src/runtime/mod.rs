//! PJRT runtime: load the AOT artifacts produced by `make artifacts`
//! (`python/compile/aot.py`) and execute them from Rust. Python is never on
//! this path — the HLO text is compiled once per process and executed with
//! concrete buffers.
//!
//! * [`XlaRuntime`] — one PJRT CPU client + executable cache.
//! * [`artifacts`] — readers for the weight/testset/manifest files.
//!
//! The PJRT backend needs the external `xla` crate, which is not part of
//! the offline build. It is gated behind the `xla` cargo feature: without
//! it, [`XlaRuntime::cpu`] returns an error (and
//! [`XlaRuntime::available`] reports `false`) so callers — the engine, the
//! e2e driver, the integration tests — can detect the stub and fall back
//! to the native kernels. The artifact *file* readers are plain `std` and
//! always available.

pub mod artifacts;

pub use artifacts::MlpArtifacts;

use std::path::{Path, PathBuf};

use anyhow::Result;
#[cfg(feature = "xla")]
use anyhow::Context;
#[cfg(feature = "xla")]
use std::collections::HashMap;

/// Typed input buffer for an executable.
#[derive(Clone, Debug)]
pub enum Arg {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl Arg {
    pub fn f32(data: Vec<f32>, dims: &[usize]) -> Arg {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        Arg::F32 {
            data,
            dims: dims.to_vec(),
        }
    }

    pub fn i32(data: Vec<i32>, dims: &[usize]) -> Arg {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        Arg::I32 {
            data,
            dims: dims.to_vec(),
        }
    }

    #[cfg(feature = "xla")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Arg::F32 { data, dims } => {
                let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Arg::I32 { data, dims } => {
                let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }
}

/// A compiled executable (one AOT'd jax function).
pub struct Executable {
    #[cfg(feature = "xla")]
    exe: xla::PjRtLoadedExecutable,
    /// Path it was loaded from (diagnostics).
    pub path: PathBuf,
}

impl Executable {
    /// Execute with the given arguments; returns the flattened f32 output
    /// of the first tuple element (all our AOT functions return 1-tuples —
    /// `return_tuple=True` in aot.py).
    #[cfg(feature = "xla")]
    pub fn run_f32(&self, args: &[Arg]) -> Result<Vec<f32>> {
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Stub: always errors — the crate was built without the `xla` feature.
    #[cfg(not(feature = "xla"))]
    pub fn run_f32(&self, _args: &[Arg]) -> Result<Vec<f32>> {
        anyhow::bail!(
            "cannot execute {}: built without the `xla` feature (PJRT backend unavailable)",
            self.path.display()
        )
    }
}

/// PJRT CPU client with an executable cache (compile once per path).
pub struct XlaRuntime {
    #[cfg(feature = "xla")]
    client: xla::PjRtClient,
    #[cfg(feature = "xla")]
    cache: HashMap<PathBuf, std::rc::Rc<Executable>>,
    #[cfg(not(feature = "xla"))]
    #[allow(dead_code)]
    _private: (),
}

impl XlaRuntime {
    /// Whether the PJRT backend was compiled in.
    pub fn available() -> bool {
        cfg!(feature = "xla")
    }

    /// Create the CPU client.
    #[cfg(feature = "xla")]
    pub fn cpu() -> Result<XlaRuntime> {
        Ok(XlaRuntime {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            cache: HashMap::new(),
        })
    }

    /// Stub: always errors — the crate was built without the `xla` feature.
    #[cfg(not(feature = "xla"))]
    pub fn cpu() -> Result<XlaRuntime> {
        anyhow::bail!(
            "PJRT backend unavailable: built without the `xla` feature \
             (use Backend::Native, or rebuild with --features xla and the \
             `xla` crate added as a dependency)"
        )
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        #[cfg(feature = "xla")]
        {
            self.client.platform_name()
        }
        #[cfg(not(feature = "xla"))]
        {
            "unavailable (built without the `xla` feature)".to_string()
        }
    }

    /// Load + compile an HLO text artifact (cached).
    #[cfg(feature = "xla")]
    pub fn load(&mut self, path: &Path) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.get(path) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let entry = std::rc::Rc::new(Executable {
            exe,
            path: path.to_path_buf(),
        });
        self.cache.insert(path.to_path_buf(), entry.clone());
        Ok(entry)
    }

    /// Stub: always errors — the crate was built without the `xla` feature.
    #[cfg(not(feature = "xla"))]
    pub fn load(&mut self, path: &Path) -> Result<std::rc::Rc<Executable>> {
        anyhow::bail!(
            "cannot compile {}: built without the `xla` feature",
            path.display()
        )
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests that need the artifacts live in
    // rust/tests/runtime_integration.rs (they require `make artifacts` to
    // have run). Here: pure argument-shape logic.
    use super::*;

    #[test]
    fn arg_shape_checked() {
        let a = Arg::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        match a {
            Arg::F32 { dims, .. } => assert_eq!(dims, vec![2, 2]),
            _ => unreachable!(),
        }
    }

    #[test]
    #[should_panic]
    fn arg_shape_mismatch_panics() {
        Arg::f32(vec![1.0; 3], &[2, 2]);
    }

    #[test]
    fn stub_reports_unavailable_without_feature() {
        if !XlaRuntime::available() {
            let err = XlaRuntime::cpu().err().expect("stub must error");
            assert!(format!("{err:#}").contains("xla"));
        }
    }
}
