//! Property-based invariants over the formats, kernels and cost model
//! (DESIGN.md §6) — randomized with the in-tree deterministic RNG (the
//! offline substitute for proptest; every case prints its seed on failure).

use cer::costmodel::{analytic, trace_matvec, DistStats, EnergyModel, TimeModel};
use cer::formats::{Cer, Cser, Csr, Dense, FormatKind, MatrixFormat};
use cer::kernels::{AnyMatrix, PackedDense};
use cer::stats::decompose::Decomposed;
use cer::stats::entropy::{matrix_entropy, max_entropy, min_entropy};
use cer::stats::synth::PlanePoint;
use cer::util::Rng;

/// Random matrix generator spanning the edge cases: arbitrary K, sparsity,
/// tiny and skewed shapes, all-zero, constant, single row/column.
fn random_matrix(rng: &mut Rng) -> Dense {
    match rng.below(12) {
        0 => Dense::zeros(1 + rng.below(6), 1 + rng.below(6)),
        1 => {
            // Single-row ternary matrix.
            let n = 1 + rng.below(50);
            let data: Vec<f32> = (0..n).map(|_| (rng.below(3) as f32) - 1.0).collect();
            Dense::from_vec(1, n, data)
        }
        _ => {
            let m = 1 + rng.below(30);
            let n = 1 + rng.below(50);
            let k = 1 + rng.below(12);
            let values: Vec<f32> = (0..k)
                .map(|i| (i as f32 - (k / 2) as f32) * 0.25)
                .collect();
            // Skewed distribution over values.
            let data: Vec<f32> = (0..m * n)
                .map(|_| {
                    let r = rng.f64();
                    let idx = ((r * r) * k as f64) as usize;
                    values[idx.min(k - 1)]
                })
                .collect();
            Dense::from_vec(m, n, data)
        }
    }
}

fn oracle_matvec(m: &Dense, x: &[f32]) -> Vec<f32> {
    (0..m.rows())
        .map(|r| {
            m.row(r)
                .iter()
                .zip(x)
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum::<f64>() as f32
        })
        .collect()
}

#[test]
fn roundtrip_all_formats_500_random_matrices() {
    let mut rng = Rng::new(0x1207);
    for case in 0..500 {
        let m = random_matrix(&mut rng);
        for kind in FormatKind::ALL {
            let enc = AnyMatrix::encode(kind, &m);
            assert_eq!(enc.to_dense(), m, "case {case} kind {kind:?}");
        }
        let p = PackedDense::from_dense(&m);
        assert_eq!(p.to_dense(), m, "case {case} packed");
    }
}

#[test]
fn matvec_equivalence_300_random_matrices() {
    let mut rng = Rng::new(0x1208);
    for case in 0..300 {
        let m = random_matrix(&mut rng);
        let x: Vec<f32> = (0..m.cols()).map(|_| rng.f32() * 4.0 - 2.0).collect();
        let want = oracle_matvec(&m, &x);
        for kind in FormatKind::ALL {
            let enc = AnyMatrix::encode(kind, &m);
            let mut y = vec![0.0f32; m.rows()];
            enc.matvec(&x, &mut y);
            for (i, (a, b)) in y.iter().zip(&want).enumerate() {
                let tol = 1e-4 * (1.0 + a.abs().max(b.abs()));
                assert!(
                    (a - b).abs() <= tol,
                    "case {case} kind {kind:?} row {i}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn decomposition_roundtrip_and_matvec() {
    let mut rng = Rng::new(0x1209);
    for _ in 0..100 {
        let m = random_matrix(&mut rng);
        let d = Decomposed::new(&m);
        assert_eq!(d.reconstruct(), m);
        let x: Vec<f32> = (0..m.cols()).map(|_| rng.f32()).collect();
        let want = oracle_matvec(&m, &x);
        let mut y = vec![0.0f32; m.rows()];
        d.matvec(FormatKind::Cser, &x, &mut y);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
        }
    }
}

#[test]
fn storage_measured_equals_analytic_equations() {
    // Eqs. (1), (3), (9), (11) — exact per-element forms must match the
    // struct accounting bit-for-bit on every matrix.
    let mut rng = Rng::new(0x120A);
    for case in 0..200 {
        let m = random_matrix(&mut rng);
        // The analytic forms assume the mode is the implicit element with
        // ω0 = 0 (the paper's standing assumption); decompose first.
        let m = Decomposed::new(&m).shifted;
        let s = DistStats::measure(&m);
        let n_total = (m.rows() * m.cols()) as f64;
        let close = |a: f64, b: f64| (a - b).abs() < 1e-6;
        assert!(close(analytic::storage_dense(), 32.0));
        let csr = Csr::from_dense(&m);
        assert!(
            close(
                analytic::storage_csr(&s),
                csr.storage().total_bits() as f64 / n_total
            ),
            "case {case} CSR"
        );
        let cer = Cer::from_dense(&m);
        assert!(
            close(
                analytic::storage_cer(&s),
                cer.storage().total_bits() as f64 / n_total
            ),
            "case {case} CER"
        );
        let cser = Cser::from_dense(&m);
        assert!(
            close(
                analytic::storage_cser(&s),
                cser.storage().total_bits() as f64 / n_total
            ),
            "case {case} CSER"
        );
    }
}

#[test]
fn traced_energy_equals_analytic_on_dense_rows() {
    // The exact energy forms assume every row is non-empty (theorem-proof
    // accounting); sample from a dense-enough distribution so that holds.
    let e = EnergyModel::table_i();
    let mut rng = Rng::new(0x120B);
    for case in 0..50 {
        let point = PlanePoint::synthesize(2.5, 0.3, 16).unwrap();
        let m = point.sample_matrix(20 + rng.below(30), 40 + rng.below(60), &mut rng);
        // The analytic forms assume ω0 = 0 is the mode (ties in the sampled
        // matrix can make another value the mode): decompose first, exactly
        // as the harness does.
        let m = Decomposed::new(&m).shifted;
        // Skip the rare sample with an empty/degenerate row.
        let cer = Cer::from_dense(&m);
        let all_rows_nonempty = (0..m.rows()).all(|r| {
            let (s0, e0) = cer.row_runs(r);
            e0 > s0
        });
        if !all_rows_nonempty {
            continue;
        }
        let s = DistStats::measure(&m);
        let n_total = (m.rows() * m.cols()) as f64;
        let traced = |k| trace_matvec(&AnyMatrix::encode(k, &m)).energy_pj(&e) / n_total;
        let close = |a: f64, b: f64| (a - b).abs() / b.max(1e-9) < 1e-9;
        assert!(close(analytic::energy_dense(&s, &e), traced(FormatKind::Dense)), "case {case} dense");
        assert!(close(analytic::energy_csr(&s, &e), traced(FormatKind::Csr)), "case {case} csr");
        assert!(close(analytic::energy_cer(&s, &e), traced(FormatKind::Cer)), "case {case} cer");
        assert!(close(analytic::energy_cser(&s, &e), traced(FormatKind::Cser)), "case {case} cser");
    }
}

#[test]
fn entropy_monotonicity_cer_storage_and_energy() {
    // Corollary 2.1 direction: at fixed p0, K, shape — lower entropy must
    // not increase CER/CSER storage or energy (averaged over samples).
    let e = EnergyModel::table_i();
    let mut rng = Rng::new(0x120C);
    // min_entropy(0.5) = 1.0, so start just above it.
    let p0 = 0.5;
    let entropies = [1.05, 1.5, 2.5, 3.2, 3.9]; // max_entropy(0.5, 64) ≈ 3.99
    let mut prev_storage = 0.0f64;
    let mut prev_energy = 0.0f64;
    for (i, &h) in entropies.iter().enumerate() {
        let point = PlanePoint::synthesize(h, p0, 64).unwrap();
        let (mut sbits, mut epj) = (0.0, 0.0);
        let samples = 5;
        for _ in 0..samples {
            let m = point.sample_matrix(100, 300, &mut rng);
            let enc = AnyMatrix::encode(FormatKind::Cer, &m);
            sbits += enc.storage().total_bits() as f64;
            epj += trace_matvec(&enc).energy_pj(&e);
        }
        if i > 0 {
            assert!(
                sbits >= prev_storage * 0.98,
                "storage not monotone: H={h} gives {sbits} after {prev_storage}"
            );
            assert!(
                epj >= prev_energy * 0.98,
                "energy not monotone: H={h} gives {epj} after {prev_energy}"
            );
        }
        prev_storage = sbits;
        prev_energy = epj;
    }
}

#[test]
fn spike_and_slab_cer_matches_csr_within_o_one_over_n() {
    // §IV-D: CSR is a specialization of CER; on spike-and-slab matrices
    // CER's energy approaches CSR's as n grows (CER even wins by avoiding
    // repeated value loads; allow it to be cheaper, bound the overhead).
    let e = EnergyModel::table_i();
    let mut rng = Rng::new(0x120D);
    let p0 = 0.9;
    let h = max_entropy(p0, 32) - 1e-6;
    let point = PlanePoint::synthesize(h, p0, 32).unwrap();
    for n in [512usize, 4096] {
        let m = point.sample_matrix(50, n, &mut rng);
        let cer = trace_matvec(&AnyMatrix::encode(FormatKind::Cer, &m)).energy_pj(&e);
        let csr = trace_matvec(&AnyMatrix::encode(FormatKind::Csr, &m)).energy_pj(&e);
        let rel = cer / csr;
        assert!(rel < 1.05, "n={n}: CER/CSR energy ratio {rel}");
    }
}

#[test]
fn renyi_bound_holds_for_synthesized_matrices() {
    // p0 ≥ 2^-H (§IV, from Rényi's generalized entropy).
    let mut rng = Rng::new(0x120E);
    for _ in 0..50 {
        let m = Decomposed::new(&random_matrix(&mut rng)).shifted;
        let s = DistStats::measure(&m);
        assert!(s.p0 >= 2f64.powf(-s.entropy) - 1e-9);
    }
}

#[test]
fn feasible_region_boundaries_consistent() {
    let mut rng = Rng::new(0x120F);
    for _ in 0..200 {
        let p0 = 0.01 + rng.f64() * 0.98;
        let k = 2 + rng.below(200);
        let (lo, hi) = (min_entropy(p0), max_entropy(p0, k));
        assert!(lo >= 0.0);
        if (k as f64) * p0 >= 1.0 {
            assert!(
                hi >= lo - 1e-9,
                "p0={p0} k={k}: max {hi} < min {lo}"
            );
        }
        // Synthesized points land inside and measure back correctly.
        if (k as f64) * p0 >= 1.0 && hi - lo > 0.1 {
            let h = lo + (hi - lo) * rng.f64();
            if let Some(point) = PlanePoint::synthesize(h, p0, k) {
                let m = point.sample_matrix(80, 80, &mut rng);
                let measured = matrix_entropy(&m);
                assert!(
                    (measured - h).abs() < 0.35,
                    "H target {h} measured {measured} (p0={p0}, k={k})"
                );
            }
        }
    }
}

#[test]
fn criterion_time_consistent_with_trace() {
    let t = TimeModel::default_model();
    let e = EnergyModel::table_i();
    let mut rng = Rng::new(0x1210);
    let m = random_matrix(&mut rng);
    for kind in FormatKind::ALL {
        let enc = AnyMatrix::encode(kind, &m);
        let c = cer::costmodel::Criterion4::evaluate(&enc, &e, &t);
        let trace = trace_matvec(&enc);
        assert_eq!(c.ops, trace.total_ops());
        assert!((c.time_ns - trace.time_ns(&t)).abs() < 1e-9);
        assert!((c.energy_pj - trace.energy_pj(&e)).abs() < 1e-9);
    }
}
